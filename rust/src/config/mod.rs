//! Typed configuration system: pipeline / experiment / serving knobs,
//! JSON-loadable with CLI overrides (`--key=value`).

use std::path::PathBuf;

use crate::json::Value;

/// Sliding-window + codec-side knobs (the paper's §6 parameters).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Window size in frames (paper: 40 s at 2 FPS, scaled — see
    /// DESIGN.md §4; ratios are what transfer).
    pub window_frames: usize,
    /// Stride as a fraction of the window (paper default 0.2).
    pub stride_frac: f64,
    /// GOP size in frames (paper default 16).
    pub gop: usize,
    /// MV threshold tau in pixels (paper default 0.25).
    pub mv_threshold: f32,
    /// Residual weight alpha in eq. 3. The paper defaults to 0 only
    /// because NVDEC exposes no residuals at runtime (§3.3.1); our
    /// software decoder does, so the default uses the full form —
    /// motion-compensation failures (e.g. high-frequency flicker, MV
    /// near zero but residual large) still count as dynamic. alpha=0
    /// reproduces the paper's hardware-constrained setting.
    pub alpha: f32,
    /// Codec quantization quality.
    pub qp: u8,
    /// Answer tokens to decode per window.
    pub decode_tokens: usize,
    /// Uplink bandwidth in Mbps.
    pub uplink_mbps: f64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            window_frames: 20,
            stride_frac: 0.2,
            gop: 16,
            mv_threshold: 0.25,
            alpha: 0.5,
            qp: 6,
            decode_tokens: 2,
            uplink_mbps: 5.0,
        }
    }
}

impl PipelineConfig {
    pub fn stride_frames(&self) -> usize {
        ((self.window_frames as f64 * self.stride_frac).round() as usize).max(1)
    }

    /// Apply a `key=value` override; returns false if unknown key.
    pub fn set(&mut self, key: &str, value: &str) -> bool {
        match key {
            "window_frames" => parse_into(value, &mut self.window_frames),
            "stride_frac" => parse_into(value, &mut self.stride_frac),
            "gop" => parse_into(value, &mut self.gop),
            "mv_threshold" => parse_into(value, &mut self.mv_threshold),
            "alpha" => parse_into(value, &mut self.alpha),
            "qp" => parse_into(value, &mut self.qp),
            "decode_tokens" => parse_into(value, &mut self.decode_tokens),
            "uplink_mbps" => parse_into(value, &mut self.uplink_mbps),
            _ => false,
        }
    }

    pub fn from_json(v: &Value) -> PipelineConfig {
        let mut c = PipelineConfig::default();
        if let Some(obj) = v.as_obj() {
            for (k, val) in obj {
                let s = match val {
                    Value::Num(n) => n.to_string(),
                    Value::Str(s) => s.clone(),
                    _ => continue,
                };
                c.set(k, &s);
            }
        }
        c
    }
}

/// Experiment-harness knobs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub pipeline: PipelineConfig,
    /// Corpus size (videos) — env CF_VIDEOS overrides for quick runs.
    pub videos: usize,
    pub frames_per_video: usize,
    /// Calibration windows per class for the probe.
    pub calibration_windows: usize,
    pub seed: u64,
    pub artifacts_dir: PathBuf,
    pub model: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            pipeline: PipelineConfig::default(),
            videos: env_usize("CF_VIDEOS", 12),
            frames_per_video: env_usize("CF_FRAMES", 96),
            calibration_windows: 16,
            seed: 2026,
            artifacts_dir: artifacts_dir(),
            model: "internvl3_sim".to_string(),
        }
    }
}

/// Serving-coordinator knobs.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub pipeline: PipelineConfig,
    /// Concurrent streams.
    pub streams: usize,
    /// Frontend worker threads (decode/prune are parallel; model
    /// execution is serialized per executor replica).
    pub frontend_workers: usize,
    /// Decode stage-pool lanes per shard (`decode_workers=`, env
    /// `CF_DECODE_WORKERS`). `1` (the default) keeps the PR-4 launched
    /// ring byte-for-byte: window decode fans out on the shared
    /// `frontend_workers` pool and the virtual clock charges the
    /// per-window sum. `> 1` (with `launch=1` and `pipeline >= 1`)
    /// switches the shard to disaggregated stage pools: window decode
    /// runs on this many dedicated bounded lanes and the virtual clock
    /// charges the decode *makespan* across them. Zero is rejected — a
    /// stage with no workers can never drain.
    pub decode_workers: usize,
    /// ViT-encode stage-pool lanes per shard (`encode_workers=`, env
    /// `CF_ENCODE_WORKERS`). Same contract as `decode_workers`, for
    /// the per-frame ViT encode stage: each lane owns its own executor
    /// replica (same backend flavour as the shard primary), so encodes
    /// physically overlap while selection and assembly stay in stream
    /// order on the shard thread. Zero is rejected.
    pub encode_workers: usize,
    /// KV pool budget in bytes, split evenly across shards
    /// ([`ServingConfig::shard_kv_budget`]).
    pub kv_budget_bytes: usize,
    /// Max queued windows before backpressure drops to the newest.
    pub queue_depth: usize,
    /// Executor replicas (shards). Streams are partitioned across
    /// shards by consistent hashing of the stream id; each shard owns
    /// its own admission queue and KV pool.
    pub num_shards: usize,
    /// Thread-pool workers driving the shards (usually == num_shards;
    /// fewer workers time-multiplex shards onto threads).
    pub workers: usize,
    /// Streams a shard admits per wave before returning to the shared
    /// pool; the remainder stays stealable by idle shards.
    pub admit_wave: usize,
    /// Cross-shard work stealing when a shard's EDF queue runs dry.
    pub steal: bool,
    /// Max cross-stream jobs fused into one prefill launch per shard
    /// (`batch=` on the CLI). 1 = job-at-a-time, the unbatched path.
    pub max_batch: usize,
    /// Patch-budget quantization, in estimated visual tokens per
    /// bucket (`batch_bucket=`): jobs co-batch only when their
    /// codec-estimated token budgets land in the same bucket, bounding
    /// cross-stream padding waste.
    pub batch_bucket: usize,
    /// Pipelined shard execution depth (`pipeline=` on the CLI): how
    /// many prepared batches may be in flight behind the executor.
    /// `0` is the strictly serial prepare -> execute -> finish loop
    /// (bit-for-bit the pre-pipelining service); `N >= 1` overlaps
    /// batch k's prepare phase (frontend decode + pruning + ViT +
    /// request assembly) with batch k-1's prefill launch, bounded by a
    /// depth-N ring.
    pub pipeline_depth: usize,
    /// Per-shard launch threads (`launch=` on the CLI): with `true`
    /// (the default) and `pipeline >= 1`, each shard moves its
    /// executor onto a dedicated launch thread
    /// (`runtime::replica::LaunchedExecutor`) so the prefill launch
    /// physically runs while the shard thread prepares the next batch
    /// — wall-clock overlap, not just the virtual model. `false` keeps
    /// the executor inline on the shard thread (the overlap is then
    /// modelled in virtual time only). Results are bit-identical
    /// either way.
    pub launch: bool,
    /// Whether `launch` was explicitly set (CLI `launch=` or env
    /// `CF_LAUNCH`) rather than left at its default. The dispatcher
    /// warns about the `launch=1` + `pipeline=0` no-op only for an
    /// *explicit* request — default configs must not be scolded for a
    /// knob the operator never touched.
    pub launch_explicit: bool,
    /// Backend pool per shard (`backend=`, env `CF_BACKEND`): `fast`
    /// (the homogeneous full-precision default), `quant` (the
    /// quantized-CPU flavour only), or `hetero` (both, each on its own
    /// launch thread, with fused prefill batches routed per batch by
    /// `route=`).
    pub backend: String,
    /// Routing policy for heterogeneous pools (`route=`, env
    /// `CF_ROUTE`): `fixed` (everything on the fast primary),
    /// `static-split` (every 2nd batch offloads, signal-blind),
    /// `codec` (the default: sparse patch-budget buckets and
    /// slack-deadline batches offload to the cheap backend), or `cost`
    /// (online-fitted per-backend cost model: each batch goes to the
    /// backend minimizing predicted completion time against its
    /// pipeline frontier, accuracy penalty as tie-break). With a
    /// single backend every policy degenerates to it.
    pub route: String,
    /// Relative cost of the quant backend (`quant_ratio=`): virtual
    /// (and, on mock replicas, wall) seconds per unit of work as a
    /// fraction of the fast backend's. Clamped to [0, 1] at use.
    pub quant_ratio: f64,
    /// Batch-aware EDF slack in seconds (`batch_slack=`): when
    /// choosing a batch seed, the shard may slip past the earliest
    /// deadline by up to this much if a denser same-bucket batch forms
    /// there. `0` (the default) is bit-identical to strict EDF
    /// seeding.
    pub batch_slack: f64,
    /// Per-stream fault containment (`quarantine=`, default on): a
    /// window whose launch or decode faults takes down only its own
    /// stream — session failed, queued windows purged, KV released —
    /// while the shard keeps serving; healthy streams stay
    /// bit-identical to a fault-free run. `quarantine=0` restores the
    /// legacy behaviour where any fault panics the whole shard.
    pub quarantine: bool,
    /// Solo re-execution attempts per faulted batch member beyond the
    /// isolation attempt (`retries=`, default 0, capped at 16 —
    /// rejected above). Transient engine faults that clear within the
    /// budget recover instead of quarantining their stream.
    pub retries: usize,
    /// Deterministic **virtual** backoff before retry `n`:
    /// `retry_backoff * n` seconds charged to the recovering member's
    /// execute time (`retry_backoff=`, default 0.01, accepted in
    /// [0, 60]). Never a wall clock, so faulted runs reproduce
    /// bit-for-bit.
    pub retry_backoff: f64,
    /// Supervised shard restarts (`restarts=`, default 0, capped at 8
    /// — rejected above): a shard that dies (quarantine off, or a
    /// fault outside the contained paths) is restarted by the
    /// dispatcher up to this many times, re-admitting its surviving
    /// streams. Exhausted restarts surface as dead shards and lost
    /// streams in the sharded report.
    pub restarts: usize,
    /// Deterministic fault-injection plan (`fault=`, env `CF_FAULT`;
    /// empty = no injection). Comma-separated `key:value` pairs —
    /// `rate:`, `streams:a+b`, `kind:transient|permanent|decode`,
    /// `nth:`, `fails:`, `seed:`, `backend:` — validated at parse time
    /// by `runtime::mock::FaultPlan::parse`; malformed specs are
    /// rejected with a printed reason.
    pub fault: String,
    /// Cross-window KV compression (`kv_compress=`, env
    /// `CF_KV_COMPRESS`, default off): blocks whose codec MV energy
    /// stays calm for `compress_after=` consecutive windows are merged
    /// 2:1 then 4:1 in the retained KV, returning budget to the shard
    /// pool. `kv_compress=0` is bit-identical to the uncompressed
    /// path.
    pub kv_compress: bool,
    /// Calm windows required per compression level
    /// (`compress_after=`, default 2, capped at 64 — rejected above):
    /// level 1 (2:1) after this many calm windows, level 2 (4:1) after
    /// twice as many.
    pub compress_after: usize,
    /// Ceiling on the cumulative per-stream accuracy-proxy penalty
    /// from compression (`compress_penalty_cap=`, default 0.05,
    /// accepted in [0, 1]); surfaced in reports like a lossy backend's
    /// `quant_penalty`.
    pub compress_penalty_cap: f64,
    /// Per-stream SLO class spec (`slo=`, env `CF_SLO`; empty = no
    /// critical streams, machinery disarmed and bit-identical to a
    /// build without it). `critical:a+b+c` marks the listed stream ids
    /// critical; `critical:every:N` marks every N-th id. Critical
    /// streams hold their deadlines under overload; besteffort streams
    /// are quant-routed, frame-skipped or shed first. Validated at
    /// parse time by `coordinator::queue::SloSpec::parse`.
    pub slo: String,
    /// Whether the overload ladder may actually *shed* work (`shed=`,
    /// env `CF_SHED`, default on): levels 2-3 frame-skip and drop
    /// queued besteffort windows. `shed=0` keeps the ladder's level
    /// tracking and reporting but never drops a window — degradation
    /// stays visible while service stays complete.
    pub shed: bool,
    /// Predictive overload detection (`predict=`, env `CF_PREDICT`,
    /// default on): when the route policy carries a cost model
    /// (`route=cost`), admission prices the queued backlog with it and
    /// escalates the degradation ladder *before* deadlines start
    /// missing (AdaCodec-style). `predict=0` — or a model-less policy
    /// — falls back to reactive deadline-miss escalation.
    pub predict: bool,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            pipeline: PipelineConfig::default(),
            streams: 4,
            frontend_workers: 4,
            decode_workers: 1,
            encode_workers: 1,
            kv_budget_bytes: 256 << 20,
            queue_depth: 16,
            num_shards: 1,
            workers: 1,
            admit_wave: 2,
            steal: true,
            max_batch: 1,
            batch_bucket: 48,
            pipeline_depth: 0,
            launch: true,
            launch_explicit: false,
            backend: "fast".to_string(),
            route: "codec".to_string(),
            quant_ratio: 0.4,
            batch_slack: 0.0,
            quarantine: true,
            retries: 0,
            retry_backoff: 0.01,
            restarts: 0,
            fault: String::new(),
            kv_compress: false,
            compress_after: 2,
            compress_penalty_cap: 0.05,
            slo: String::new(),
            shed: true,
            predict: true,
        }
    }
}

impl ServingConfig {
    /// Apply a `key=value` override; serving keys first, then pipeline
    /// keys. `workers=N` is the one-knob scale-out: it sets both the
    /// shard count and the thread-pool size.
    pub fn set(&mut self, key: &str, value: &str) -> bool {
        let accepted = match key {
            "workers" => {
                if parse_into(value, &mut self.workers) {
                    self.num_shards = self.workers.max(1);
                    true
                } else {
                    false
                }
            }
            "num_shards" | "shards" => parse_into(value, &mut self.num_shards),
            "streams" => parse_into(value, &mut self.streams),
            "frontend_workers" => parse_into(value, &mut self.frontend_workers),
            "decode_workers" => parse_stage_workers(key, value, &mut self.decode_workers),
            "encode_workers" => parse_stage_workers(key, value, &mut self.encode_workers),
            "kv_budget_bytes" => parse_into(value, &mut self.kv_budget_bytes),
            "queue_depth" => parse_into(value, &mut self.queue_depth),
            "admit_wave" => parse_into(value, &mut self.admit_wave),
            "steal" => parse_flag(value, &mut self.steal),
            "batch" | "max_batch" => parse_into(value, &mut self.max_batch),
            "batch_bucket" => parse_into(value, &mut self.batch_bucket),
            "pipeline" | "pipeline_depth" => parse_into(value, &mut self.pipeline_depth),
            "launch" => {
                let ok = parse_flag(value, &mut self.launch);
                self.launch_explicit |= ok;
                ok
            }
            "backend" => parse_choice(value, &mut self.backend, &["fast", "quant", "hetero"]),
            "route" => {
                parse_choice(value, &mut self.route, &["fixed", "static-split", "codec", "cost"])
            }
            "quant_ratio" => parse_into(value, &mut self.quant_ratio),
            "batch_slack" => parse_into(value, &mut self.batch_slack),
            "quarantine" => parse_flag(value, &mut self.quarantine),
            "retries" => parse_capped_usize(key, value, &mut self.retries, 16),
            "retry_backoff" => parse_bounded_f64(key, value, &mut self.retry_backoff, 60.0),
            "restarts" => parse_capped_usize(key, value, &mut self.restarts, 8),
            "fault" => parse_fault_spec(value, &mut self.fault),
            "kv_compress" => parse_flag(value, &mut self.kv_compress),
            "compress_after" => parse_capped_usize(key, value, &mut self.compress_after, 64),
            "compress_penalty_cap" => {
                parse_bounded_f64(key, value, &mut self.compress_penalty_cap, 1.0)
            }
            "slo" => parse_slo_spec(value, &mut self.slo),
            "shed" => parse_flag(value, &mut self.shed),
            "predict" => parse_flag(value, &mut self.predict),
            _ => self.pipeline.set(key, value),
        };
        // The docs contract, both directions: knob_keys ⊆ set is unit-
        // tested; set ⊆ knob_keys is enforced here (pipeline
        // pass-through keys included), so a new match arm added
        // without a knob_keys entry — and therefore without a
        // docs/OPERATIONS.md row — trips the first debug-build use.
        debug_assert!(
            !accepted || Self::knob_keys().contains(&key),
            "knob `{key}` accepted by set() but missing from knob_keys()"
        );
        accepted
    }

    /// Every key [`ServingConfig::set`] accepts (aliases included,
    /// pipeline pass-through keys last). This is the single source of
    /// truth the operator's guide is checked against: a test asserts
    /// each key both parses here and appears in the knob table of
    /// `docs/OPERATIONS.md`, so the doc cannot drift from the parser.
    pub fn knob_keys() -> &'static [&'static str] {
        &[
            "workers",
            "shards",
            "num_shards",
            "streams",
            "frontend_workers",
            "decode_workers",
            "encode_workers",
            "kv_budget_bytes",
            "queue_depth",
            "admit_wave",
            "steal",
            "batch",
            "max_batch",
            "batch_bucket",
            "pipeline",
            "pipeline_depth",
            "launch",
            "backend",
            "route",
            "quant_ratio",
            "batch_slack",
            "quarantine",
            "retries",
            "retry_backoff",
            "restarts",
            "fault",
            "kv_compress",
            "compress_after",
            "compress_penalty_cap",
            "slo",
            "shed",
            "predict",
            "window_frames",
            "stride_frac",
            "gop",
            "mv_threshold",
            "alpha",
            "qp",
            "decode_tokens",
            "uplink_mbps",
        ]
    }

    /// Current value of every knob in [`ServingConfig::knob_keys`],
    /// in the same order (aliases repeat the canonical value). This is
    /// what the bench result cache hashes
    /// ([`crate::bench::config_key`]): covering *every* accepted knob
    /// means a cached figure can never mask a behaviour change riding
    /// in on a knob the key forgot — a unit test pins the two lists to
    /// each other, so adding a knob to `set()`/`knob_keys()` without a
    /// value here fails the build's tests.
    pub fn knob_values(&self) -> Vec<(&'static str, String)> {
        let p = &self.pipeline;
        vec![
            ("workers", self.workers.to_string()),
            ("shards", self.num_shards.to_string()),
            ("num_shards", self.num_shards.to_string()),
            ("streams", self.streams.to_string()),
            ("frontend_workers", self.frontend_workers.to_string()),
            ("decode_workers", self.decode_workers.to_string()),
            ("encode_workers", self.encode_workers.to_string()),
            ("kv_budget_bytes", self.kv_budget_bytes.to_string()),
            ("queue_depth", self.queue_depth.to_string()),
            ("admit_wave", self.admit_wave.to_string()),
            ("steal", self.steal.to_string()),
            ("batch", self.max_batch.to_string()),
            ("max_batch", self.max_batch.to_string()),
            ("batch_bucket", self.batch_bucket.to_string()),
            ("pipeline", self.pipeline_depth.to_string()),
            ("pipeline_depth", self.pipeline_depth.to_string()),
            ("launch", self.launch.to_string()),
            ("backend", self.backend.clone()),
            ("route", self.route.clone()),
            ("quant_ratio", format!("{}", self.quant_ratio)),
            ("batch_slack", format!("{}", self.batch_slack)),
            ("quarantine", self.quarantine.to_string()),
            ("retries", self.retries.to_string()),
            ("retry_backoff", format!("{}", self.retry_backoff)),
            ("restarts", self.restarts.to_string()),
            ("fault", self.fault.clone()),
            ("kv_compress", self.kv_compress.to_string()),
            ("compress_after", self.compress_after.to_string()),
            ("compress_penalty_cap", format!("{}", self.compress_penalty_cap)),
            ("slo", self.slo.clone()),
            ("shed", self.shed.to_string()),
            ("predict", self.predict.to_string()),
            ("window_frames", p.window_frames.to_string()),
            ("stride_frac", format!("{}", p.stride_frac)),
            ("gop", p.gop.to_string()),
            ("mv_threshold", format!("{}", p.mv_threshold)),
            ("alpha", format!("{}", p.alpha)),
            ("qp", p.qp.to_string()),
            ("decode_tokens", p.decode_tokens.to_string()),
            ("uplink_mbps", format!("{}", p.uplink_mbps)),
        ]
    }

    /// Per-shard KV budget: the global budget split evenly, so one
    /// shard's memory pressure cannot evict another shard's caches.
    pub fn shard_kv_budget(&self) -> usize {
        (self.kv_budget_bytes / self.num_shards.max(1)).max(1)
    }
}

/// Stage-pool worker-count syntax (`decode_workers=`,
/// `encode_workers=`): a positive integer. Zero parses but is
/// *rejected with a printed reason* — a stage pool with no workers can
/// never drain, and silently treating it as "disabled" would hide the
/// typo from the operator. The slot is left untouched on rejection,
/// same as every other knob.
fn parse_stage_workers(key: &str, value: &str, slot: &mut usize) -> bool {
    let mut parsed = 0usize;
    if !parse_into(value, &mut parsed) {
        return false;
    }
    if parsed == 0 {
        eprintln!(
            "codecflow: rejected `{key}=0`: stage pools need at least one worker \
             (use `{key}=1` for the non-disaggregated default)"
        );
        return false;
    }
    *slot = parsed;
    true
}

/// Capped count syntax (`retries=`, `restarts=`): a non-negative
/// integer no larger than `cap`. Values above the cap are *rejected
/// with a printed reason* — an absurd retry/restart budget turns a
/// permanent fault into an unbounded re-execution loop, and silently
/// clamping would hide the typo from the operator. The slot is left
/// untouched on rejection, same as every other knob.
fn parse_capped_usize(key: &str, value: &str, slot: &mut usize, cap: usize) -> bool {
    let mut parsed = 0usize;
    if !parse_into(value, &mut parsed) {
        return false;
    }
    if parsed > cap {
        eprintln!("codecflow: rejected `{key}={parsed}`: the accepted range is 0..={cap}");
        return false;
    }
    *slot = parsed;
    true
}

/// Bounded seconds syntax (`retry_backoff=`): a finite number in
/// `[0, max]`. Out-of-range values are rejected with a printed reason
/// and the slot left untouched.
fn parse_bounded_f64(key: &str, value: &str, slot: &mut f64, max: f64) -> bool {
    let mut parsed = 0.0f64;
    if !parse_into(value, &mut parsed) {
        return false;
    }
    if !parsed.is_finite() || parsed < 0.0 || parsed > max {
        eprintln!("codecflow: rejected `{key}={value}`: the accepted range is 0..={max}");
        return false;
    }
    *slot = parsed;
    true
}

/// Fault-injection spec syntax (`fault=`, env `CF_FAULT`): validated
/// end to end by [`crate::runtime::mock::FaultPlan::parse`] so a
/// malformed plan is rejected *here*, with the parser's reason printed
/// — not discovered as a silently inert knob mid-run. The empty string
/// (no injection) is always accepted.
fn parse_fault_spec(value: &str, slot: &mut String) -> bool {
    let v = value.trim();
    if v.is_empty() {
        slot.clear();
        return true;
    }
    match crate::runtime::mock::FaultPlan::parse(v) {
        Ok(_) => {
            *slot = v.to_string();
            true
        }
        Err(reason) => {
            eprintln!("codecflow: rejected `fault={v}`: {reason}");
            false
        }
    }
}

/// SLO class spec syntax (`slo=`, env `CF_SLO`): validated end to end
/// by [`crate::coordinator::queue::SloSpec::parse`] so a malformed
/// spec is rejected *here*, with the parser's reason printed — not
/// discovered as a silently inert knob mid-run. The empty string (no
/// critical streams) is always accepted.
fn parse_slo_spec(value: &str, slot: &mut String) -> bool {
    let v = value.trim();
    if v.is_empty() {
        slot.clear();
        return true;
    }
    match crate::coordinator::queue::SloSpec::parse(v) {
        Ok(_) => {
            *slot = v.to_string();
            true
        }
        Err(reason) => {
            eprintln!("codecflow: rejected `slo={v}`: {reason}");
            false
        }
    }
}

fn parse_into<T: std::str::FromStr>(value: &str, slot: &mut T) -> bool {
    match value.parse() {
        Ok(v) => {
            *slot = v;
            true
        }
        Err(_) => false,
    }
}

/// Enumerated knob syntax (`backend=`, `route=`): the value must be
/// one of `allowed` (case-insensitive, stored lowercased); anything
/// else is rejected and the slot untouched — a typo'd policy name
/// must not silently select a default.
fn parse_choice(value: &str, slot: &mut String, allowed: &[&str]) -> bool {
    let v = value.trim().to_ascii_lowercase();
    if allowed.contains(&v.as_str()) {
        *slot = v;
        true
    } else {
        false
    }
}

/// Boolean knob syntax, shared by the CLI (`steal=`, `launch=`) and
/// the env overrides ([`env_bool`]): `1`/`0`, `true`/`false`,
/// `yes`/`no`, `on`/`off`, case-insensitive. Returns false (value
/// rejected, slot untouched) on anything else.
fn parse_flag(value: &str, slot: &mut bool) -> bool {
    match value.trim().to_ascii_lowercase().as_str() {
        "1" | "true" | "yes" | "on" => {
            *slot = true;
            true
        }
        "0" | "false" | "no" | "off" => {
            *slot = false;
            true
        }
        _ => false,
    }
}

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Boolean env knob: accepts `1`/`0`, `true`/`false`, `yes`/`no`,
/// `on`/`off` (case-insensitive), so `CF_LAUNCH=false` means what the
/// matching CLI syntax (`launch=false`) means instead of silently
/// falling back to the default. Unset or unrecognized values yield
/// `default`.
pub fn env_bool(key: &str, default: bool) -> bool {
    let mut value = default;
    if let Ok(v) = std::env::var(key) {
        parse_flag(&v, &mut value);
    }
    value
}

/// Locate the artifacts directory (repo-root relative, env override).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CF_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from cwd looking for artifacts/manifest.json.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = PipelineConfig::default();
        assert_eq!(c.stride_frames(), 4); // 20% of 20
        assert_eq!(c.gop, 16);
        assert!((c.mv_threshold - 0.25).abs() < 1e-6);
    }

    #[test]
    fn overrides() {
        let mut c = PipelineConfig::default();
        assert!(c.set("gop", "8"));
        assert_eq!(c.gop, 8);
        assert!(c.set("stride_frac", "0.5"));
        assert_eq!(c.stride_frames(), 10);
        assert!(!c.set("nope", "1"));
        assert!(!c.set("gop", "xyz"));
    }

    #[test]
    fn serving_overrides_and_shard_budget() {
        let mut c = ServingConfig::default();
        assert!(c.set("workers", "4"));
        assert_eq!(c.workers, 4);
        assert_eq!(c.num_shards, 4, "workers= sets the shard count too");
        assert!(c.set("shards", "2"));
        assert_eq!(c.num_shards, 2);
        assert_eq!(c.workers, 4, "shards= leaves the pool size alone");
        assert!(c.set("steal", "false"));
        assert!(!c.steal);
        assert!(c.set("batch", "8"));
        assert_eq!(c.max_batch, 8);
        assert!(c.set("max_batch", "4"), "long form accepted too");
        assert_eq!(c.max_batch, 4);
        assert!(c.set("batch_bucket", "96"));
        assert_eq!(c.batch_bucket, 96);
        assert_eq!(c.pipeline_depth, 0, "serial service by default");
        assert!(c.set("pipeline", "2"));
        assert_eq!(c.pipeline_depth, 2);
        assert!(c.set("pipeline_depth", "1"), "long form accepted too");
        assert_eq!(c.pipeline_depth, 1);
        assert!(c.launch, "launch threads on by default");
        assert!(!c.launch_explicit, "defaulted launch is not an explicit request");
        assert!(c.set("launch", "false"));
        assert!(!c.launch);
        assert!(c.launch_explicit, "setting launch= marks it explicit");
        assert!(c.set("launch", "true"));
        assert!(c.launch);
        // Boolean knobs take the full flag syntax, same as the env
        // overrides — `launch=0` must not be silently ignored.
        assert!(c.set("launch", "0"));
        assert!(!c.launch);
        assert!(c.set("launch", "on"));
        assert!(c.launch);
        assert!(c.set("steal", "YES"));
        assert!(c.steal);
        assert!(c.set("steal", "0"));
        assert!(!c.steal);
        assert!(!c.set("launch", "maybe"), "unrecognized flag value rejected");
        assert!(c.launch, "rejected value leaves the knob untouched");
        assert!(c.set("gop", "8"), "pipeline keys pass through");
        assert_eq!(c.pipeline.gop, 8);
        assert!(!c.set("nope", "1"));

        // Heterogeneous-backend knobs.
        assert_eq!(c.backend, "fast", "homogeneous by default");
        assert_eq!(c.route, "codec");
        assert!((c.quant_ratio - 0.4).abs() < 1e-12);
        assert_eq!(c.batch_slack, 0.0, "strict EDF seeding by default");
        assert!(c.set("backend", "hetero"));
        assert_eq!(c.backend, "hetero");
        assert!(c.set("backend", "QUANT"), "choices are case-insensitive");
        assert_eq!(c.backend, "quant");
        assert!(!c.set("backend", "gpu"), "unknown pool rejected");
        assert_eq!(c.backend, "quant", "rejected value leaves the knob untouched");
        assert!(c.set("route", "static-split"));
        assert_eq!(c.route, "static-split");
        assert!(c.set("route", "fixed"));
        assert!(!c.set("route", "random"), "unknown policy rejected");
        assert_eq!(c.route, "fixed");
        assert!(c.set("quant_ratio", "0.25"));
        assert!((c.quant_ratio - 0.25).abs() < 1e-12);
        assert!(c.set("batch_slack", "1.5"));
        assert!((c.batch_slack - 1.5).abs() < 1e-12);

        // Stage-pool knobs: positive counts accepted, zero rejected
        // with the slot untouched (a poolless stage can never drain).
        assert_eq!(c.decode_workers, 1, "stage pools off by default");
        assert_eq!(c.encode_workers, 1);
        assert!(c.set("decode_workers", "3"));
        assert_eq!(c.decode_workers, 3);
        assert!(c.set("encode_workers", "2"));
        assert_eq!(c.encode_workers, 2);
        assert!(!c.set("decode_workers", "0"), "zero workers rejected");
        assert_eq!(c.decode_workers, 3, "rejected value leaves the knob untouched");
        assert!(!c.set("encode_workers", "0"), "zero workers rejected");
        assert_eq!(c.encode_workers, 2);
        assert!(!c.set("decode_workers", "many"), "non-numeric rejected");
        assert_eq!(c.decode_workers, 3);

        c.kv_budget_bytes = 100;
        c.num_shards = 4;
        assert_eq!(c.shard_kv_budget(), 25);
        c.num_shards = 0; // degenerate: treated as one shard
        assert_eq!(c.shard_kv_budget(), 100);
    }

    #[test]
    fn knob_keys_all_parse_and_list_is_exhaustive_for_rejects() {
        // Every advertised knob must be accepted by the parser (the
        // operator's-guide test layers the doc check on top of this).
        for key in ServingConfig::knob_keys() {
            let mut c = ServingConfig::default();
            let value = match *key {
                "steal" | "launch" | "quarantine" | "kv_compress" | "shed" | "predict" => "true",
                "stride_frac" => "0.5",
                "mv_threshold" | "alpha" => "0.25",
                "backend" => "hetero",
                "route" => "codec",
                "quant_ratio" => "0.5",
                "fault" => "rate:0.5",
                "compress_penalty_cap" => "0.5",
                "slo" => "critical:every:2",
                _ => "2",
            };
            assert!(c.set(key, value), "knob_keys lists `{key}` but set() rejects it");
        }
        // And a key outside the list is rejected.
        assert!(!ServingConfig::default().set("not_a_knob", "1"));
    }

    #[test]
    fn knob_values_cover_every_knob_in_order() {
        // The bench result cache hashes knob_values(); this pin is what
        // makes "the cache key covers every serving knob" a build-time
        // property instead of a convention.
        let keys: Vec<&str> =
            ServingConfig::default().knob_values().into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            ServingConfig::knob_keys().to_vec(),
            "knob_values() must mirror knob_keys() exactly (same keys, same order)"
        );
    }

    #[test]
    fn knob_values_reflect_every_override() {
        // Setting any advertised knob to a non-default value must change
        // the recorded value list — the property the bench cache key
        // invalidation test builds on.
        let base = ServingConfig::default().knob_values();
        for key in ServingConfig::knob_keys() {
            let mut c = ServingConfig::default();
            let value = match *key {
                "steal" | "launch" | "quarantine" | "shed" | "predict" => "false",
                // kv_compress defaults to off: flip it on to be visible.
                "kv_compress" => "true",
                "stride_frac" => "0.35",
                "mv_threshold" => "0.75",
                "alpha" => "0.9",
                "backend" => "hetero",
                "route" => "fixed",
                "quant_ratio" => "0.77",
                "batch_slack" => "3.5",
                "fault" => "rate:0.5",
                "compress_penalty_cap" => "0.4",
                "slo" => "critical:0",
                _ => "7",
            };
            assert!(c.set(key, value), "knob `{key}` must parse");
            assert_ne!(
                c.knob_values(),
                base,
                "overriding `{key}` must be visible in knob_values()"
            );
        }
    }

    #[test]
    fn fault_knobs_parse_and_reject_out_of_range_values() {
        let mut c = ServingConfig::default();
        assert!(c.quarantine, "containment on by default");
        assert_eq!(c.retries, 0);
        assert!((c.retry_backoff - 0.01).abs() < 1e-12);
        assert_eq!(c.restarts, 0);
        assert_eq!(c.fault, "", "no injection by default");

        assert!(c.set("quarantine", "0"));
        assert!(!c.quarantine);
        assert!(c.set("quarantine", "on"));
        assert!(c.quarantine);
        assert!(!c.set("quarantine", "maybe"), "unrecognized flag rejected");

        // Retry/restart budgets are capped; out-of-range is an error,
        // not a clamp.
        assert!(c.set("retries", "3"));
        assert_eq!(c.retries, 3);
        assert!(c.set("retries", "16"), "cap itself accepted");
        assert_eq!(c.retries, 16);
        assert!(!c.set("retries", "17"), "above the cap rejected");
        assert_eq!(c.retries, 16, "rejected value leaves the knob untouched");
        assert!(!c.set("retries", "-1"), "negative rejected (unsigned parse)");
        assert!(!c.set("retries", "lots"), "non-numeric rejected");
        assert!(c.set("restarts", "2"));
        assert_eq!(c.restarts, 2);
        assert!(c.set("restarts", "8"), "cap itself accepted");
        assert!(!c.set("restarts", "9"), "above the cap rejected");
        assert_eq!(c.restarts, 8);

        // Backoff is bounded seconds.
        assert!(c.set("retry_backoff", "0.5"));
        assert!((c.retry_backoff - 0.5).abs() < 1e-12);
        assert!(c.set("retry_backoff", "0"));
        assert_eq!(c.retry_backoff, 0.0);
        assert!(!c.set("retry_backoff", "61"), "above 60s rejected");
        assert!(!c.set("retry_backoff", "-0.1"), "negative rejected");
        assert!(!c.set("retry_backoff", "inf"), "non-finite rejected");
        assert!(!c.set("retry_backoff", "soon"), "non-numeric rejected");
        assert_eq!(c.retry_backoff, 0.0, "rejected values leave the knob untouched");

        // Fault specs are validated end to end at parse time.
        assert!(c.set("fault", "rate:0.25,kind:transient,nth:2,fails:2,seed:7"));
        assert_eq!(c.fault, "rate:0.25,kind:transient,nth:2,fails:2,seed:7");
        assert!(c.set("fault", "streams:3+5,kind:decode,nth:1"));
        assert!(c.set("fault", ""), "empty spec clears the plan");
        assert_eq!(c.fault, "");
        for bad in [
            "rate:2",            // rate outside [0, 1]
            "rate:abc",          // unparseable number
            "kind:explosive",    // unknown kind
            "rate:0.5,nth:0",    // nth is 1-based
            "rate:0.5,fails:0",  // zero failures is no fault
            "rate:0.5,bogus:1",  // unknown key
            "rate:0.5,seed",     // not a key:value pair
            "kind:permanent",    // targets nothing (no rate, no streams)
            "backend:gpu",       // unknown backend scope
        ] {
            assert!(!c.set("fault", bad), "malformed spec {bad:?} must be rejected");
            assert_eq!(c.fault, "", "rejected spec leaves the knob untouched");
        }
    }

    #[test]
    fn compression_knobs_parse_and_reject_out_of_range_values() {
        let mut c = ServingConfig::default();
        assert!(!c.kv_compress, "compression off by default");
        assert_eq!(c.compress_after, 2);
        assert!((c.compress_penalty_cap - 0.05).abs() < 1e-12);

        assert!(c.set("kv_compress", "1"));
        assert!(c.kv_compress);
        assert!(c.set("kv_compress", "off"));
        assert!(!c.kv_compress);
        assert!(!c.set("kv_compress", "maybe"), "unrecognized flag rejected");

        assert!(c.set("compress_after", "5"));
        assert_eq!(c.compress_after, 5);
        assert!(c.set("compress_after", "64"), "cap itself accepted");
        assert!(!c.set("compress_after", "65"), "above the cap rejected");
        assert_eq!(c.compress_after, 64, "rejected value leaves the knob untouched");
        assert!(!c.set("compress_after", "soon"), "non-numeric rejected");

        assert!(c.set("compress_penalty_cap", "0.3"));
        assert!((c.compress_penalty_cap - 0.3).abs() < 1e-12);
        assert!(c.set("compress_penalty_cap", "1"), "bound itself accepted");
        assert!(!c.set("compress_penalty_cap", "1.5"), "above 1 rejected");
        assert!(!c.set("compress_penalty_cap", "-0.1"), "negative rejected");
        assert!(!c.set("compress_penalty_cap", "inf"), "non-finite rejected");
        assert!((c.compress_penalty_cap - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slo_knobs_parse_and_reject_malformed_specs() {
        let mut c = ServingConfig::default();
        assert_eq!(c.slo, "", "no critical streams by default");
        assert!(c.shed, "shedding armed by default");
        assert!(c.predict, "predictive escalation armed by default");

        assert!(c.set("route", "cost"), "the cost policy is a valid route choice");
        assert_eq!(c.route, "cost");

        assert!(c.set("slo", "critical:3+7+12"));
        assert_eq!(c.slo, "critical:3+7+12");
        assert!(c.set("slo", "critical:every:4"));
        assert_eq!(c.slo, "critical:every:4");
        assert!(c.set("slo", ""), "empty spec clears the classes");
        assert_eq!(c.slo, "");
        for bad in ["besteffort:1", "critical:every:0", "critical:one", "critical:every:x"] {
            assert!(!c.set("slo", bad), "malformed spec {bad:?} must be rejected");
            assert_eq!(c.slo, "", "rejected spec leaves the knob untouched");
        }

        assert!(c.set("shed", "0"));
        assert!(!c.shed);
        assert!(c.set("shed", "on"));
        assert!(c.shed);
        assert!(!c.set("shed", "maybe"), "unrecognized flag rejected");
        assert!(c.set("predict", "false"));
        assert!(!c.predict);
        assert!(c.set("predict", "1"));
        assert!(c.predict);
        assert!(!c.set("predict", "perhaps"), "unrecognized flag rejected");
    }

    #[test]
    fn env_bool_understands_cli_style_values() {
        let key = "CF_TEST_ENV_BOOL_KNOB"; // unique: no other test reads it
        assert!(env_bool(key, true), "unset -> default");
        assert!(!env_bool(key, false));
        for (value, expect) in [
            ("0", false),
            ("false", false),
            ("FALSE", false),
            ("off", false),
            ("1", true),
            ("true", true),
            ("YES", true),
        ] {
            std::env::set_var(key, value);
            assert_eq!(env_bool(key, !expect), expect, "value {value:?}");
        }
        std::env::set_var(key, "maybe");
        assert!(env_bool(key, true), "unrecognized -> default");
        std::env::remove_var(key);
    }

    #[test]
    fn from_json() {
        let v = Value::parse(r#"{"gop": 4, "mv_threshold": 1.5}"#).unwrap();
        let c = PipelineConfig::from_json(&v);
        assert_eq!(c.gop, 4);
        assert!((c.mv_threshold - 1.5).abs() < 1e-6);
    }
}
