//! CodecFlow: codec-guided end-to-end optimization for streaming video
//! analytics — a full-system reproduction (see DESIGN.md).
//!
//! The full architecture narrative — the layer map below expanded,
//! plus a request's life from bitstream to `ShardedReport` and where
//! batching / stealing / backpressure intercept it — lives in
//! [`docs/ARCHITECTURE.md`](../docs/ARCHITECTURE.md) at the
//! repository root. The operator's reference (every serving knob:
//! default, env override, interaction matrix, measuring figure) is
//! [`docs/OPERATIONS.md`](../docs/OPERATIONS.md).
//!
//! Layer map:
//! * [`codec`], [`video`], [`net`] — substrates: a software inter-frame
//!   video codec exposing motion vectors / residuals / GOP structure,
//!   a synthetic surveillance corpus, and an uplink simulator.
//! * [`pipeline`], [`vision`], [`kvc`] — the paper's contribution:
//!   single-pass decode + window forming, codec-guided token pruning,
//!   selective KV-cache refresh with RoPE position correction.
//! * [`runtime`], [`model`] — PJRT execution of the AOT-compiled JAX/
//!   Pallas artifacts (feature `pjrt`; manifest-only stub otherwise),
//!   per-shard executor replica factories, launch-thread executor
//!   ownership and heterogeneous backend pools
//!   ([`runtime::replica`]: `Send` executors behind bounded lanes,
//!   `fast` + quantized-`quant` flavours routed per batch by
//!   [`runtime::batch::RoutePolicy`]), cross-stream batched execution
//!   ([`runtime::batch`]), model descriptors, the anomaly probe.
//! * [`coordinator`], [`baselines`] — the serving layer, single-shard
//!   ([`coordinator::serve`]) and sharded: consistent stream->shard
//!   placement, per-shard EDF admission queues and KV budgets,
//!   within-shard cross-stream batch formation
//!   ([`coordinator::queue::AdmissionQueue::pop_batch`]), pipelined
//!   batch execution (`pipeline=N` overlaps a batch's prepare with
//!   the previous batch's prefill launch inside every shard —
//!   physically so under `launch=1`, with measured wall overlap in
//!   the reports), cross-shard work stealing driven by a thread
//!   pool ([`coordinator::shard`], [`coordinator::dispatch`]), and
//!   per-stream fault containment (quarantine + bounded retry, with
//!   supervised shard restart above it) — plus the four comparison
//!   systems.
//! * [`exp`] — one experiment runner per paper table/figure, plus
//!   [`exp::fig20_scaling`] (shard-scaling throughput),
//!   [`exp::fig21_batching`] (cross-stream batched prefill),
//!   [`exp::fig22_pipeline`] (pipelined shard execution),
//!   [`exp::fig23_wallclock`] (launch-thread wall-clock overlap),
//!   [`exp::fig24_hetero`] (heterogeneous backends with codec-guided
//!   routing), [`exp::fig25_stages`] (disaggregated stage pools),
//!   [`exp::fig26_faults`] (availability under seeded fault
//!   injection) and [`exp::fig27_kvcompress`] (cross-window KV
//!   compression capacity), beyond the paper.
//! * [`bench`] — continuous benchmarking: schema-versioned
//!   `BENCH_<fig>.json` records emitted by the fig20–fig27 runners,
//!   the `codecflow bench run` small-config trajectory with its
//!   knob-covering result cache, and the `codecflow bench compare`
//!   regression gate CI runs against the committed `baselines/`.
//! * [`util`], [`json`], [`config`] — support: PRNG, stats, micro-bench
//!   harness, property-test helper, panic-isolating thread pool with
//!   join/fan-in and bounded single-owner lanes ([`util::threadpool`]),
//!   JSON, typed configs.

pub mod baselines;
pub mod bench;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod json;
pub mod kvc;
pub mod model;
pub mod net;
pub mod pipeline;
pub mod runtime;
pub mod util;
pub mod video;
pub mod vision;
