//! CodecFlow: codec-guided end-to-end optimization for streaming video
//! analytics — a full-system reproduction (see DESIGN.md).
//!
//! Layer map:
//! * [`codec`], [`video`], [`net`] — substrates: a software inter-frame
//!   video codec exposing motion vectors / residuals / GOP structure,
//!   a synthetic surveillance corpus, and an uplink simulator.
//! * [`pipeline`], [`vision`], [`kvc`] — the paper's contribution:
//!   single-pass decode + window forming, codec-guided token pruning,
//!   selective KV-cache refresh with RoPE position correction.
//! * [`runtime`], [`model`] — PJRT execution of the AOT-compiled JAX/
//!   Pallas artifacts, model descriptors, the anomaly probe.
//! * [`coordinator`], [`baselines`] — the serving layer (sessions,
//!   router, batcher, metrics) and the four comparison systems.
//! * [`exp`] — one experiment runner per paper table/figure.
//! * [`util`], [`json`], [`config`] — support: PRNG, stats, micro-bench
//!   harness, property-test helper, JSON, typed configs.

pub mod baselines;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod exp;
pub mod json;
pub mod kvc;
pub mod model;
pub mod net;
pub mod pipeline;
pub mod runtime;
pub mod util;
pub mod video;
pub mod vision;
