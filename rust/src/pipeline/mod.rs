//! The streaming front-end and per-window inference assembly.
//!
//! * [`frontend`] — ingestion: compressed-bitstream vs per-frame JPEG
//!   transport, single-pass decode with a shared temporal buffer
//!   (CodecFlow) vs per-window redundant decode (baseline), stage
//!   timing for the Fig 3 breakdown;
//! * [`preprocess`] — CPU multi-pass vs fused patch extraction;
//! * [`infer`] — the window engine: composes pruning, ViT encoding,
//!   KV reuse/refresh and decoding into one per-window step,
//!   parameterized by [`infer::VariantOpts`] so CodecFlow and all four
//!   baselines share one code path (the comparison isolates policies,
//!   not plumbing).

pub mod frontend;
pub mod infer;
pub mod preprocess;

pub use frontend::{DecodedFrame, Frontend, FrontendMode, StreamSource};
pub use infer::{KvcMode, RefreshSelect, StageTimes, VariantOpts, WindowEngine, WindowResult};
