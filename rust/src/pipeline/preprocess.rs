//! Preprocessing: decoded frame -> normalized patch tensors.
//!
//! Two implementations with identical outputs (paper §3.2):
//! * [`naive`]: the baseline CPU path — separate colorspace, resize
//!   and normalize passes with intermediate buffers, then a per-patch
//!   gather (the structure of a PIL/torchvision preprocess);
//! * [`fused`]: CodecFlow's single fused pass straight into the patch
//!   buffer (the GPU-preproc equivalent: no intermediate traffic).

use crate::codec::types::Frame;
use crate::vision::layout::PatchLayout;

/// Extract every patch in `patch_list` into a flat [n, patch_dim]
/// buffer — separate passes with intermediate allocations.
pub fn naive(layout: &PatchLayout, frame: &Frame, patch_list: &[usize]) -> Vec<f32> {
    // pass 1: u8 -> f32 "colorspace conversion"
    let as_f32: Vec<f32> = frame.data.iter().map(|&v| v as f32).collect();
    // pass 2: "resize" (identity here, but a real pass over the data)
    let resized: Vec<f32> = as_f32.iter().map(|&v| v).collect();
    // pass 3: normalize
    let normalized: Vec<f32> = resized.iter().map(|&v| (v - 128.0) / 64.0).collect();
    // pass 4: per-patch gather
    let pd = layout.patch * layout.patch;
    let mut out = vec![0.0f32; patch_list.len() * pd];
    for (j, &p) in patch_list.iter().enumerate() {
        let (px, py) = layout.patch_xy(p);
        for y in 0..layout.patch {
            for x in 0..layout.patch {
                out[j * pd + y * layout.patch + x] =
                    normalized[(py * layout.patch + y) * frame.w + px * layout.patch + x];
            }
        }
    }
    out
}

/// Fused single pass: gather + convert + normalize per element.
pub fn fused(layout: &PatchLayout, frame: &Frame, patch_list: &[usize]) -> Vec<f32> {
    let pd = layout.patch * layout.patch;
    let mut out = vec![0.0f32; patch_list.len() * pd];
    for (j, &p) in patch_list.iter().enumerate() {
        layout.extract_patch(frame, p, &mut out[j * pd..(j + 1) * pd]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn naive_and_fused_agree() {
        let layout = PatchLayout::new(64, 64, 8, 2);
        let mut rng = Rng::new(9);
        let mut frame = Frame::new(64, 64);
        for v in frame.data.iter_mut() {
            *v = rng.below(256) as u8;
        }
        let patches: Vec<usize> = vec![0, 5, 17, 63];
        let a = naive(&layout, &frame, &patches);
        let b = fused(&layout, &frame, &patches);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_selection() {
        let layout = PatchLayout::new(64, 64, 8, 2);
        let frame = Frame::new(64, 64);
        assert!(fused(&layout, &frame, &[]).is_empty());
        assert!(naive(&layout, &frame, &[]).is_empty());
    }
}
