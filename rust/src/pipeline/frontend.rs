//! Stream ingestion front-end (paper §3.2).
//!
//! Two transport/decode modes:
//! * [`FrontendMode::Jpeg`] — the baseline: each sampled frame is
//!   JPEG-coded and transmitted individually; every window decodes all
//!   of its frames, so overlapping windows re-decode the same frames;
//! * [`FrontendMode::Bitstream`] — CodecFlow: the inter-coded
//!   bitstream is transmitted once; a *single sequential decode pass*
//!   fills a temporal buffer shared by all overlapping windows, and
//!   codec metadata (MVs, residuals, frame types) falls out of the
//!   same pass.
//!
//! Transmission is modelled by [`crate::net::Link`] on real payload
//! sizes from the real codecs; decode times are measured wall-clock.

use std::sync::Arc;

use crate::codec::decoder::Decoder;
use crate::codec::encoder::{encode_sequence, EncoderConfig};
use crate::codec::jpeg;
use crate::codec::types::{Frame, FrameMeta, FrameType};
use crate::net::Link;
use crate::util;

/// One decoded frame + its codec metadata, shared by reference.
/// Overlapping windows (and the pipelined shard loop's in-flight
/// batches) all point at the same decoded pixels — producing a window
/// never deep-copies a frame.
pub type DecodedFrame = Arc<(Frame, FrameMeta)>;

/// A camera-side source: the encoded form of one video.
pub struct StreamSource {
    /// Inter-coded bitstream of the whole clip.
    pub bitstream: Vec<u8>,
    /// Per-frame JPEG payloads (baseline transport).
    pub jpegs: Vec<Vec<u8>>,
    pub frames: usize,
}

impl StreamSource {
    /// Encode a clip both ways (camera-side work, not serving cost).
    pub fn encode(frames: &[Frame], gop: usize, qp: u8) -> StreamSource {
        let (bitstream, _) = encode_sequence(
            frames,
            EncoderConfig { gop, qp, ..Default::default() },
        );
        let jpegs = frames.iter().map(|f| jpeg::encode(f, qp)).collect();
        StreamSource { bitstream, jpegs, frames: frames.len() }
    }

    pub fn bitstream_bytes(&self) -> usize {
        self.bitstream.len()
    }

    pub fn jpeg_bytes_total(&self) -> usize {
        self.jpegs.iter().map(|j| j.len()).sum()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontendMode {
    Jpeg,
    Bitstream,
}

/// Per-window front-end output.
pub struct WindowFrames {
    /// (frame, meta) for [start, end), shared with the frontend's
    /// temporal buffer (`Arc` per frame — no pixel copies). JPEG mode
    /// synthesizes metadata with `FrameType::I` and no MVs (no codec
    /// signal available).
    pub frames: Vec<DecodedFrame>,
    pub start: usize,
    pub end: usize,
    /// Seconds of transmission attributable to this window.
    pub transmit_s: f64,
    /// Seconds of decode work done for this window.
    pub decode_s: f64,
}

/// Serving-side front-end state for one stream.
pub struct Frontend {
    pub mode: FrontendMode,
    link: Link,
    source: StreamSource,
    /// Temporal buffer: decoded (frame, meta), filled sequentially in
    /// Bitstream mode (each frame decoded exactly once) and handed to
    /// windows by `Arc` so overlap never copies pixels.
    buffer: Vec<DecodedFrame>,
    /// Persistent sequential decoder (Bitstream mode).
    decoder: Option<Decoder>,
    /// Total stream bits already "transmitted" (Bitstream mode).
    transmitted_frames: usize,
    /// Cumulative stage seconds (reporting).
    pub total_transmit_s: f64,
    pub total_decode_s: f64,
}

impl Frontend {
    pub fn new(mode: FrontendMode, link: Link, source: StreamSource) -> Frontend {
        let decoder = match mode {
            FrontendMode::Bitstream => {
                Some(Decoder::new(source.bitstream.clone()).expect("bitstream header"))
            }
            FrontendMode::Jpeg => None,
        };
        Frontend {
            mode,
            link,
            source,
            buffer: Vec::new(),
            decoder,
            transmitted_frames: 0,
            total_transmit_s: 0.0,
            total_decode_s: 0.0,
        }
    }

    pub fn total_frames(&self) -> usize {
        self.source.frames
    }

    /// Produce the frames for window [start, end).
    pub fn window(&mut self, start: usize, end: usize) -> WindowFrames {
        match self.mode {
            FrontendMode::Jpeg => self.window_jpeg(start, end),
            FrontendMode::Bitstream => self.window_bitstream(start, end),
        }
    }

    /// Baseline: transmit + decode every frame of the window (overlap
    /// frames transmitted once — cameras don't resend — but decoded
    /// again for every window they appear in).
    fn window_jpeg(&mut self, start: usize, end: usize) -> WindowFrames {
        // Transmission: only newly arrived frames cross the link.
        let new_lo = self.transmitted_frames.max(start);
        let sizes: Vec<usize> =
            (new_lo..end).map(|i| self.source.jpegs[i].len()).collect();
        let transmit_s = if sizes.is_empty() { 0.0 } else { self.link.transmit_batch_s(&sizes) };
        self.transmitted_frames = self.transmitted_frames.max(end);

        let t0 = util::now();
        let mut frames = Vec::with_capacity(end - start);
        for i in start..end {
            // Redundant decode: no shared buffer across windows.
            let f = jpeg::decode(&self.source.jpegs[i]).expect("jpeg decode");
            let (w, h) = (f.w, f.h);
            frames.push(Arc::new((
                f,
                FrameMeta {
                    frame_type: FrameType::I,
                    gop_pos: 0,
                    mb_w: w / crate::codec::types::MB,
                    mb_h: h / crate::codec::types::MB,
                    mvs: Vec::new(),
                    residual_sad: Vec::new(),
                    bits: self.source.jpegs[i].len() * 8,
                },
            )));
        }
        let decode_s = util::now() - t0;
        self.total_transmit_s += transmit_s;
        self.total_decode_s += decode_s;
        WindowFrames { frames, start, end, transmit_s, decode_s }
    }

    /// CodecFlow: single-pass decode into the shared temporal buffer;
    /// transmission covers only the bits of newly needed frames.
    fn window_bitstream(&mut self, start: usize, end: usize) -> WindowFrames {
        // Decode forward exactly once (sequential single pass); the
        // persistent decoder continues where it stopped last window.
        let t0 = util::now();
        let dec = self.decoder.as_mut().expect("bitstream mode");
        while self.buffer.len() < end {
            match dec.next_frame().expect("decode") {
                Some((f, m)) => self.buffer.push(Arc::new((f, m))),
                None => break,
            }
        }
        let decode_s = util::now() - t0;

        // Transmission: bits of frames newly required.
        let new_lo = self.transmitted_frames.max(start);
        let mut bits = 0usize;
        for i in new_lo..end {
            bits += self.buffer[i].1.bits;
        }
        let transmit_s = if bits == 0 {
            0.0
        } else {
            self.link.transmit_s(bits / 8)
        };
        self.transmitted_frames = self.transmitted_frames.max(end);

        // `to_vec` on an `Arc` buffer clones refcounts, not pixels:
        // every overlapping window shares the single decoded copy.
        let frames = self.buffer[start..end].to_vec();
        self.total_transmit_s += transmit_s;
        self.total_decode_s += decode_s;
        WindowFrames { frames, start, end, transmit_s, decode_s }
    }

    /// Transmission comparison payloads (Fig 3 / Fig 11 Trans bars).
    pub fn source_sizes(&self) -> (usize, usize) {
        (self.source.jpeg_bytes_total(), self.source.bitstream_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::video::{Corpus, CorpusConfig};

    fn test_source() -> (StreamSource, usize) {
        let corpus = Corpus::generate(CorpusConfig {
            videos: 1,
            frames_per_video: 24,
            ..Default::default()
        });
        let frames = &corpus.clips[0].frames;
        (StreamSource::encode(frames, 8, 6), frames.len())
    }

    #[test]
    fn bitstream_smaller_than_jpegs() {
        let (src, _) = test_source();
        assert!(
            src.bitstream_bytes() < src.jpeg_bytes_total(),
            "bitstream {} vs jpeg {}",
            src.bitstream_bytes(),
            src.jpeg_bytes_total()
        );
    }

    #[test]
    fn both_modes_yield_same_window_shape() {
        let (src, n) = test_source();
        let (src2, _) = test_source();
        let mut fj = Frontend::new(FrontendMode::Jpeg, Link::default(), src);
        let mut fb = Frontend::new(FrontendMode::Bitstream, Link::default(), src2);
        let wj = fj.window(0, 10.min(n));
        let wb = fb.window(0, 10.min(n));
        assert_eq!(wj.frames.len(), wb.frames.len());
        // decoded content should be visually close (different codecs)
        let psnr = wj.frames[0].0.psnr(&wb.frames[0].0);
        assert!(psnr > 25.0, "psnr={psnr}");
    }

    #[test]
    fn bitstream_mode_has_codec_metadata() {
        let (src, _) = test_source();
        let mut fb = Frontend::new(FrontendMode::Bitstream, Link::default(), src);
        let w = fb.window(0, 12);
        assert_eq!(w.frames[0].1.frame_type, FrameType::I);
        assert_eq!(w.frames[1].1.frame_type, FrameType::P);
        assert!(!w.frames[1].1.mvs.is_empty());
        // jpeg mode: no MVs
        let (src2, _) = test_source();
        let mut fj = Frontend::new(FrontendMode::Jpeg, Link::default(), src2);
        let wj = fj.window(0, 12);
        assert!(wj.frames[1].1.mvs.is_empty());
    }

    #[test]
    fn single_pass_decode_shares_overlap() {
        let (src, _) = test_source();
        let mut fb = Frontend::new(FrontendMode::Bitstream, Link::default(), src);
        let w1 = fb.window(0, 12);
        assert!(w1.decode_s >= 0.0);
        // second overlapping window: only 4 new frames decoded
        let w2 = fb.window(4, 16);
        assert_eq!(w2.frames.len(), 12);
        assert_eq!(w2.frames[0].0, fb.buffer[4].0);
        // zero-copy: the window points at the buffer's decoded frame,
        // it does not hold a deep copy of the pixels.
        for (i, f) in w2.frames.iter().enumerate() {
            assert!(
                std::sync::Arc::ptr_eq(f, &fb.buffer[4 + i]),
                "window frame {i} must share the buffer allocation"
            );
        }
        // overlapping windows share frames with each other too
        assert!(std::sync::Arc::ptr_eq(&w1.frames[4], &w2.frames[0]));
        // transmission only charged once per frame
        let w3 = fb.window(4, 16);
        assert_eq!(w3.transmit_s, 0.0);
    }

    #[test]
    fn jpeg_mode_redecodes_overlap() {
        let (src, _) = test_source();
        let mut fj = Frontend::new(FrontendMode::Jpeg, Link::default(), src);
        let _ = fj.window(0, 12);
        let d1 = fj.total_decode_s;
        let _ = fj.window(4, 16); // 8 overlap frames re-decoded
        assert!(fj.total_decode_s > d1);
    }
}
