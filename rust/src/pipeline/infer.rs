//! The window engine: one sliding-window inference step, composed of
//! codec-guided pruning, ViT encoding, selective KVC refresh and
//! answer decoding — parameterized so that CodecFlow and all four
//! baselines run through the same plumbing (paper §5 "Baselines").
//!
//! Per-variant knobs ([`VariantOpts`]):
//! * `prune` — codec-guided token pruning before the ViT (§3.3);
//! * `vit_pixel_reuse` — Déjà Vu-style per-patch pixel-diff reuse of
//!   cached ViT outputs (pixel-domain cost is *measured*, not waived);
//! * `kvc` — LLM prefill mode: full recompute vs overlap reuse with a
//!   refresh-selection policy (§3.4);
//! * `fused_preproc` — fused vs multi-pass preprocessing (§3.2).
//!
//! Sequence-order invariant: `WindowState.tokens[i]` corresponds to
//! token i of `WindowState.{k,v}`, and tokens are stored in ascending
//! sequence-position order (visual by (frame, group), then text).

use crate::codec::types::{Frame, FrameMeta, FrameType};
use crate::kvc::block::KvBlock;
use crate::kvc::records::{TokenKind, TokenRecord, WindowState};
use crate::kvc::refresher::{compress_partition, plan_window, CompressPolicy, RefreshPolicy};
use crate::kvc::rope;
use crate::model::prompt::Prompt;
use crate::runtime::batch::{BatchOutcome, BatchRequest};
use crate::runtime::flops;
use crate::runtime::manifest::ModelSpec;
use crate::runtime::mock::Executor;
use crate::runtime::tensor::Tensor;
use crate::util;
use crate::vision::analyzer::MotionAnalyzer;
use crate::vision::layout::PatchLayout;
use crate::vision::pruner::{FrameSelection, PrunerConfig, TokenPruner};

use super::frontend::DecodedFrame;
use super::preprocess;

/// Refresh-selection policy per window (variant-specific).
#[derive(Clone, Debug)]
pub enum RefreshSelect {
    /// CodecFlow: I-frame anchor tokens.
    Anchors,
    /// Naive full reuse.
    None,
    /// CacheBlend emulation: top-`frac` of overlap tokens by pixel-
    /// domain change score (computed online, cost measured).
    TopKByChange { frac: f64 },
    /// VLCache emulation: fixed `frac`, uniformly spaced (content-
    /// blind ratio from offline profiling).
    FixedRatio { frac: f64 },
}

#[derive(Clone, Debug)]
pub enum KvcMode {
    /// Full prefill every window.
    Recompute,
    /// Reuse overlap KV with the given refresh selection.
    Reuse(RefreshSelect),
}

#[derive(Clone, Debug)]
pub struct VariantOpts {
    pub prune: Option<PrunerConfig>,
    pub alpha: f32,
    /// Déjà Vu: reuse ViT outputs for patches whose mean absolute
    /// pixel diff vs the previous frame is below this threshold.
    pub vit_pixel_reuse: Option<f32>,
    pub kvc: KvcMode,
    pub fused_preproc: bool,
    pub decode_tokens: usize,
}

impl VariantOpts {
    pub fn fullcomp() -> Self {
        VariantOpts {
            prune: None,
            alpha: 0.0,
            vit_pixel_reuse: None,
            kvc: KvcMode::Recompute,
            fused_preproc: false,
            decode_tokens: 2,
        }
    }

    pub fn codecflow(tau: f32, alpha: f32) -> Self {
        VariantOpts {
            prune: Some(PrunerConfig { tau }),
            alpha,
            vit_pixel_reuse: None,
            kvc: KvcMode::Reuse(RefreshSelect::Anchors),
            fused_preproc: true,
            decode_tokens: 2,
        }
    }
}

/// Cross-window KV compression configuration (serving knobs
/// `kv_compress=` / `compress_after=` / `compress_penalty_cap=`,
/// threaded by the shard at admit time). Strictly opt-in: an engine
/// without it set is bit-identical to the pre-compression path.
#[derive(Clone, Copy, Debug)]
pub struct CompressionCfg {
    /// Calm-window schedule (see [`CompressPolicy`]).
    pub policy: CompressPolicy,
    /// Ceiling on the cumulative per-stream accuracy-proxy penalty;
    /// surfaced like `quant_penalty` in serving reports.
    pub penalty_cap: f64,
    /// A window is *calm* when every frame's mean codec MV magnitude
    /// stays below this (the pipeline's `mv_threshold` by default).
    pub calm_threshold: f32,
}

/// Cumulative compression activity of one engine (stream).
#[derive(Clone, Copy, Debug, Default)]
pub struct CompressStats {
    /// Merge steps applied (one per level transition).
    pub events: u64,
    /// Tokens merged away across all steps.
    pub merged_tokens: u64,
    /// KV + embedding bytes returned to the pool.
    pub bytes_saved: u64,
    /// Accuracy-proxy penalty, clamped to `penalty_cap` — the analog
    /// of a lossy backend's `quant_penalty` for lossy KV retention.
    pub penalty: f64,
}

/// Per-stage seconds for one window.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub transmit: f64,
    pub decode: f64,
    pub preprocess: f64,
    pub vit: f64,
    pub llm_prefill: f64,
    pub llm_decode: f64,
    /// Token-selection overhead (Fig 19 "Token Pruning").
    pub overhead_prune: f64,
    /// KVC planning + position correction overhead (Fig 19 "KVC").
    pub overhead_kvc: f64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.transmit
            + self.decode
            + self.preprocess
            + self.vit
            + self.llm_prefill
            + self.llm_decode
            + self.overhead_prune
            + self.overhead_kvc
    }

    pub fn add(&mut self, o: &StageTimes) {
        self.transmit += o.transmit;
        self.decode += o.decode;
        self.preprocess += o.preprocess;
        self.vit += o.vit;
        self.llm_prefill += o.llm_prefill;
        self.llm_decode += o.llm_decode;
        self.overhead_prune += o.overhead_prune;
        self.overhead_kvc += o.overhead_kvc;
    }
}

/// Outcome of one window.
#[derive(Clone, Debug)]
pub struct WindowResult {
    pub start: usize,
    pub end: usize,
    pub last_hidden: Vec<f32>,
    /// Masked mean-pooled final hidden state (the probe readout).
    pub pooled: Vec<f32>,
    pub logits: Vec<f32>,
    pub decoded_ids: Vec<i32>,
    pub seq_tokens: usize,
    pub visual_tokens: usize,
    pub reused_tokens: usize,
    pub refreshed_tokens: usize,
    pub fresh_tokens: usize,
    /// 1 - retained/possible visual tokens over fresh frames.
    pub pruned_ratio: f64,
    /// Useful (unpadded) FLOPs.
    pub flops: u64,
    /// Padded FLOPs actually executed (bucket slack included).
    pub flops_padded: u64,
    pub times: StageTimes,
}

/// One visual token ready for sequence assembly.
struct VisualToken {
    frame: usize,
    group: usize,
    is_iframe: bool,
    emb: Vec<f32>,
}

/// Where a sequence position of an incremental window comes from.
enum Src {
    Reused { prev_idx: usize },
    Refresh { prev_idx: usize },
    Fresh { fresh_idx: usize },
    Text { text_idx: usize },
}

/// One assembled sequence position (incremental-path continuation).
struct SeqTok {
    src: Src,
    frame: usize,
    group: usize,
    is_iframe: bool,
}

/// A window prepared up to (but *not* including) its LLM prefill
/// launch. [`WindowEngine::prepare_window`] returns the launch itself
/// as a [`BatchRequest`] so the serving layer can fuse
/// shape-compatible launches from different streams into one
/// `execute_batch` call; this struct carries everything needed to
/// consume the launch outputs in [`WindowEngine::finish_window`].
pub struct PendingWindow {
    start: usize,
    end: usize,
    times: StageTimes,
    flops: u64,
    flops_padded: u64,
    pruned_ratio: f64,
    path: PendingPath,
}

impl PendingWindow {
    /// Stage seconds already incurred by the prepare phase (frontend
    /// transmit + decode, preprocessing, ViT encode, selection/KVC
    /// overheads up to the launch). This is the portion of a window's
    /// service the pipelined shard loop can hide behind the previous
    /// batch's prefill launch; the remainder of the final
    /// `StageTimes::total` is the launch itself plus the finish phase.
    pub fn prepare_s(&self) -> f64 {
        self.times.total()
    }
}

/// One frame's ViT encode detached from the engine, so a stage-pool
/// worker owning its own executor replica can run it off the shard
/// thread ([`crate::coordinator::shard`]'s encode pool). Produced by
/// [`WindowEngine::plan_encode`]; its output folds back in through
/// [`WindowEngine::prepare_window_preencoded`]. `run` replicates the
/// non-Déjà-Vu body of the inline `encode_frame` exactly — same
/// preprocessing, bucketing and `vit_encode` launch — so the folded
/// result is bit-identical to the single-threaded path.
pub struct EncodeJob {
    frame: DecodedFrame,
    abs_frame: usize,
    selection: FrameSelection,
    layout: PatchLayout,
    model: String,
    spec: ModelSpec,
    fused_preproc: bool,
}

/// Output of one [`EncodeJob::run`]: the frame's visual tokens plus
/// the stage seconds / FLOPs the encode incurred and the wall
/// interval it occupied on its worker (per-stage utilization).
pub struct EncodedFrame {
    tokens: Vec<VisualToken>,
    preprocess_s: f64,
    vit_s: f64,
    flops: u64,
    flops_padded: u64,
    pub wall_start: f64,
    pub wall_end: f64,
}

impl EncodedFrame {
    /// Virtual stage seconds this encode contributes to its window
    /// (preprocess + ViT execute).
    pub fn stage_s(&self) -> f64 {
        self.preprocess_s + self.vit_s
    }
}

impl EncodeJob {
    /// Absolute frame index this job encodes.
    pub fn abs_frame(&self) -> usize {
        self.abs_frame
    }

    /// Run the ViT encode against `exec` — any replica of the planning
    /// engine's executor. Pure with respect to engine state.
    pub fn run(&self, exec: &dyn Executor) -> EncodedFrame {
        let wall_start = util::now();
        let mut out = EncodedFrame {
            tokens: Vec::new(),
            preprocess_s: 0.0,
            vit_s: 0.0,
            flops: 0,
            flops_padded: 0,
            wall_start,
            wall_end: wall_start,
        };
        let sel = &self.selection;
        if sel.groups.is_empty() {
            out.wall_end = util::now();
            return out;
        }

        let frame = &self.frame.0;
        let patch_list: Vec<usize> =
            sel.groups.iter().flat_map(|&g| self.layout.group_patches(g)).collect();
        let t0 = util::now();
        let patches = if self.fused_preproc {
            preprocess::fused(&self.layout, frame, &patch_list)
        } else {
            preprocess::naive(&self.layout, frame, &patch_list)
        };
        out.preprocess_s += util::now() - t0;

        // Bucket + pad.
        let n = patch_list.len();
        let bucket = ModelSpec::pick_bucket(&self.spec.vit_buckets, n);
        let pd = self.spec.patch_dim;
        let mut padded = vec![0.0f32; bucket * pd];
        padded[..n * pd].copy_from_slice(&patches);
        let mut pos_ids = vec![0i32; bucket];
        for (j, &p) in patch_list.iter().enumerate() {
            pos_ids[j] = p as i32;
        }
        let mut mask = vec![0.0f32; bucket];
        mask[..n].fill(1.0);

        let (outputs, exec_s) = exec
            .execute(
                &self.model,
                &format!("vit_encode_n{bucket}"),
                &[
                    Tensor::f32(&[bucket, pd], padded),
                    Tensor::i32(&[bucket], pos_ids),
                    Tensor::f32(&[bucket], mask),
                ],
            )
            .expect("vit_encode");
        out.vit_s += exec_s;
        out.flops += flops::vit_encode(&self.spec, n);
        out.flops_padded += flops::vit_encode(&self.spec, bucket);

        let d = self.spec.llm_dim;
        let toks = outputs[0].as_f32();
        for (j, &g) in sel.groups.iter().enumerate() {
            out.tokens.push(VisualToken {
                frame: self.abs_frame,
                group: g,
                is_iframe: sel.is_iframe,
                emb: toks[j * d..(j + 1) * d].to_vec(),
            });
        }
        // Sort by group for deterministic sequence order.
        out.tokens.sort_by_key(|t| t.group);
        out.wall_end = util::now();
        out
    }
}

enum PendingPath {
    /// Full prefill (first window, Recompute mode, or bucket-overflow
    /// fallback).
    Full {
        visual: Vec<VisualToken>,
        text_len: usize,
        t_real: usize,
        bucket: usize,
    },
    /// Incremental prefill: reuse overlap KV, refresh per policy.
    Incr {
        prev: WindowState,
        seq: Vec<SeqTok>,
        fresh: Vec<VisualToken>,
        corrected_k: KvBlock,
        gathered_v: KvBlock,
        text_len: usize,
        to_real: usize,
        tn_real: usize,
        tn_bucket: usize,
        to_bucket: usize,
        refreshed: usize,
    },
}

/// Per-stream window engine.
pub struct WindowEngine<'a> {
    exec: &'a dyn Executor,
    pub model: String,
    pub spec: ModelSpec,
    pub opts: VariantOpts,
    layout: PatchLayout,
    analyzer: MotionAnalyzer,
    pruner: TokenPruner,
    prompt: Prompt,
    /// Frames the pruner has consumed (selections are made once, in
    /// stream order, and remembered).
    selections: Vec<FrameSelection>,
    prev: Option<WindowState>,
    /// Déjà Vu state: previous frame + its per-group ViT outputs.
    dv_prev_frame: Option<Frame>,
    dv_prev_tokens: Vec<Option<Vec<f32>>>,
    /// Cached prompt embeddings (context-free lookup).
    text_emb: Option<Vec<Vec<f32>>>,
    /// Change scores per (frame, group) for CacheBlend selection.
    change_scores: std::collections::HashMap<(usize, usize), f32>,
    /// Cross-window KV compression (None = disabled, bit-identical to
    /// the pre-compression path).
    compression: Option<CompressionCfg>,
    /// Mean codec MV magnitude per absolute frame (parallel to
    /// `selections`; only maintained while compression is enabled).
    mv_energy: Vec<f32>,
    /// Consecutive windows whose every frame stayed below the calm
    /// threshold.
    calm_windows: usize,
    compress_stats: CompressStats,
}

impl<'a> WindowEngine<'a> {
    pub fn new(exec: &'a dyn Executor, model: &str, opts: VariantOpts) -> Self {
        let spec = exec.spec(model).expect("model spec");
        let layout = PatchLayout::new(spec.frame, spec.frame, spec.patch, spec.merge);
        let pruner_cfg = opts.prune.unwrap_or(PrunerConfig { tau: -1.0 }); // tau<0 => keep all
        WindowEngine {
            exec,
            model: model.to_string(),
            prompt: Prompt::from_spec(&spec),
            layout,
            analyzer: MotionAnalyzer::new(opts.alpha),
            pruner: TokenPruner::new(layout, pruner_cfg),
            opts,
            spec,
            selections: Vec::new(),
            prev: None,
            dv_prev_frame: None,
            dv_prev_tokens: Vec::new(),
            text_emb: None,
            change_scores: std::collections::HashMap::new(),
            compression: None,
            mv_energy: Vec::new(),
            calm_windows: 0,
            compress_stats: CompressStats::default(),
        }
    }

    /// Reset per-stream state (new stream on the same engine).
    pub fn reset(&mut self) {
        self.selections.clear();
        self.prev = None;
        self.dv_prev_frame = None;
        self.dv_prev_tokens.clear();
        self.change_scores.clear();
        self.mv_energy.clear();
        self.calm_windows = 0;
    }

    /// Enable cross-window KV compression (serving layer, at admit).
    pub fn set_compression(&mut self, cfg: CompressionCfg) {
        self.compression = Some(cfg);
    }

    /// Cumulative compression activity of this stream.
    pub fn compress_stats(&self) -> CompressStats {
        self.compress_stats
    }

    /// Ensure pruning selections exist for frames [0, upto) given the
    /// decoded window content; frames must be offered in stream order.
    fn ensure_selections(&mut self, frames: &[DecodedFrame], abs_start: usize) {
        for (i, df) in frames.iter().enumerate() {
            let meta = &df.1;
            let abs = abs_start + i;
            if abs < self.selections.len() {
                continue;
            }
            debug_assert_eq!(abs, self.selections.len(), "frames out of order");
            if let Some(c) = self.compression {
                // Codec-guided calm signal: masked mean MV magnitude,
                // free at decode time (a byproduct of parsing the
                // bitstream).
                self.mv_energy.push(frame_mv_energy(meta, c.calm_threshold));
            }
            let sel = if self.opts.prune.is_some() {
                let mask = self.analyzer.analyze(&self.layout, meta);
                self.pruner.select(&mask)
            } else {
                // No pruning: everything retained; I-frame flag kept
                // for anchor policy (falls back to GOP position when
                // metadata is absent, e.g. JPEG transport).
                let all_groups: Vec<usize> = (0..self.layout.tokens_per_frame()).collect();
                FrameSelection {
                    patches: all_groups
                        .iter()
                        .flat_map(|&g| self.layout.group_patches(g))
                        .collect(),
                    groups: all_groups,
                    is_iframe: meta.frame_type == FrameType::I,
                    total_patches: self.layout.patches_per_frame(),
                    total_groups: self.layout.tokens_per_frame(),
                }
            };
            self.selections.push(sel);
        }
    }

    /// Run the ViT for one frame's retained patches; returns tokens
    /// per retained group.
    fn encode_frame(
        &mut self,
        frame: &Frame,
        abs_frame: usize,
        times: &mut StageTimes,
        flops: &mut u64,
        flops_padded: &mut u64,
    ) -> Vec<VisualToken> {
        let sel = self.selections[abs_frame].clone();
        if sel.groups.is_empty() {
            return Vec::new();
        }

        // Déjà Vu: split groups into reused (pixel-static) and fresh.
        let mut groups = sel.groups.clone();
        let mut reused: Vec<(usize, Vec<f32>)> = Vec::new();
        if let Some(thresh) = self.opts.vit_pixel_reuse {
            let t0 = util::now();
            if let Some(prev_f) = &self.dv_prev_frame {
                let mut fresh = Vec::new();
                for &g in &groups {
                    let diff = group_pixel_mad(&self.layout, frame, prev_f, g);
                    match (diff < thresh, self.dv_prev_tokens.get(g).and_then(|t| t.clone())) {
                        (true, Some(tok)) => reused.push((g, tok)),
                        _ => fresh.push(g),
                    }
                }
                groups = fresh;
            }
            times.overhead_prune += util::now() - t0;
        }

        let mut out: Vec<VisualToken> = reused
            .into_iter()
            .map(|(g, emb)| VisualToken {
                frame: abs_frame,
                group: g,
                is_iframe: sel.is_iframe,
                emb,
            })
            .collect();

        if !groups.is_empty() {
            // Preprocess retained patches.
            let patch_list: Vec<usize> =
                groups.iter().flat_map(|&g| self.layout.group_patches(g)).collect();
            let t0 = util::now();
            let patches = if self.opts.fused_preproc {
                preprocess::fused(&self.layout, frame, &patch_list)
            } else {
                preprocess::naive(&self.layout, frame, &patch_list)
            };
            times.preprocess += util::now() - t0;

            // Bucket + pad.
            let n = patch_list.len();
            let bucket = ModelSpec::pick_bucket(&self.spec.vit_buckets, n);
            let pd = self.spec.patch_dim;
            let mut padded = vec![0.0f32; bucket * pd];
            padded[..n * pd].copy_from_slice(&patches);
            let mut pos_ids = vec![0i32; bucket];
            for (j, &p) in patch_list.iter().enumerate() {
                pos_ids[j] = p as i32;
            }
            let mut mask = vec![0.0f32; bucket];
            mask[..n].fill(1.0);

            let (outputs, exec_s) = self
                .exec
                .execute(
                    &self.model,
                    &format!("vit_encode_n{bucket}"),
                    &[
                        Tensor::f32(&[bucket, pd], padded),
                        Tensor::i32(&[bucket], pos_ids),
                        Tensor::f32(&[bucket], mask),
                    ],
                )
                .expect("vit_encode");
            times.vit += exec_s;
            *flops += flops::vit_encode(&self.spec, n);
            *flops_padded += flops::vit_encode(&self.spec, bucket);

            let d = self.spec.llm_dim;
            let toks = outputs[0].as_f32();
            for (j, &g) in groups.iter().enumerate() {
                out.push(VisualToken {
                    frame: abs_frame,
                    group: g,
                    is_iframe: sel.is_iframe,
                    emb: toks[j * d..(j + 1) * d].to_vec(),
                });
            }
        }

        // Update Déjà Vu cache (per-group outputs of this frame).
        if self.opts.vit_pixel_reuse.is_some() {
            let mut cache = vec![None; self.layout.tokens_per_frame()];
            for t in &out {
                cache[t.group] = Some(t.emb.clone());
            }
            self.dv_prev_tokens = cache;
            self.dv_prev_frame = Some(frame.clone());
        }

        // Sort by group for deterministic sequence order.
        out.sort_by_key(|t| t.group);
        out
    }

    fn text_embeddings(&mut self, times: &mut StageTimes) -> Vec<Vec<f32>> {
        if let Some(t) = &self.text_emb {
            return t.clone();
        }
        let (out, exec_s) = self
            .exec
            .execute(&self.model, "embed_text", &[self.prompt.tensor()])
            .expect("embed_text");
        times.llm_prefill += exec_s;
        let d = self.spec.llm_dim;
        let flat = out[0].as_f32();
        let embs: Vec<Vec<f32>> =
            (0..self.prompt.len()).map(|i| flat[i * d..(i + 1) * d].to_vec()).collect();
        self.text_emb = Some(embs.clone());
        embs
    }

    /// Process window [start, end) given its decoded frames (+ stage
    /// times already incurred by the front-end). Equivalent to
    /// [`WindowEngine::prepare_window`] + a solo prefill launch +
    /// [`WindowEngine::finish_window`] — the batched serving path runs
    /// the same code, so a batch of one reproduces this bit-for-bit.
    pub fn process_window(
        &mut self,
        frames: &[DecodedFrame],
        start: usize,
        frontend_times: StageTimes,
    ) -> WindowResult {
        let (request, pending) = self.prepare_window(frames, start, frontend_times);
        let (outputs, exec_s) = self
            .exec
            .execute(&request.model, &request.artifact, &request.inputs)
            .expect("prefill");
        self.finish_window(pending, BatchOutcome { outputs, exec_s, quant_penalty: 0.0 })
    }

    /// Run everything *before* the window's LLM prefill launch:
    /// frontend-time accounting, codec-guided selection, ViT encoding
    /// of fresh frames, sequence assembly and KV gather/position
    /// correction. Returns the fully-materialized prefill launch as a
    /// [`BatchRequest`] (so the serving layer may fuse it with
    /// shape-compatible launches from other streams) plus the
    /// [`PendingWindow`] continuation for
    /// [`WindowEngine::finish_window`].
    pub fn prepare_window(
        &mut self,
        frames: &[DecodedFrame],
        start: usize,
        frontend_times: StageTimes,
    ) -> (BatchRequest, PendingWindow) {
        let end = start + frames.len();
        let mut times = frontend_times;
        let mut flops = 0u64;
        let mut flops_padded = 0u64;

        self.ensure_selections(frames, start);
        self.update_change_scores(frames, start);

        let (reuse_possible, fresh_lo) = self.fresh_range(start);

        let mut fresh_tokens: Vec<VisualToken> = Vec::new();
        let mut possible = 0usize;
        let mut retained = 0usize;
        for abs in fresh_lo..end {
            let idx = abs - start;
            // Shared (`Arc`) frame: encoded straight out of the
            // frontend's temporal buffer, no per-window pixel copy.
            let toks =
                self.encode_frame(&frames[idx].0, abs, &mut times, &mut flops, &mut flops_padded);
            possible += self.layout.tokens_per_frame();
            retained += toks.len();
            fresh_tokens.extend(toks);
        }
        let pruned_ratio =
            if possible == 0 { 0.0 } else { 1.0 - retained as f64 / possible as f64 };

        let text_embs = self.text_embeddings(&mut times);

        if reuse_possible {
            self.incremental_prepare(start, end, fresh_tokens, &text_embs, times, flops, flops_padded, pruned_ratio)
        } else {
            self.full_prepare(start, end, fresh_tokens, &text_embs, times, flops, flops_padded, pruned_ratio)
        }
    }

    /// Which frames of window [start, ..) need fresh ViT tokens:
    /// returns (overlap KV is reusable, first fresh frame index).
    fn fresh_range(&self, start: usize) -> (bool, usize) {
        let reuse_possible = matches!(self.opts.kvc, KvcMode::Reuse(_))
            && self.prev.as_ref().map(|p| p.end_frame > start && p.start_frame <= start)
                == Some(true);
        let fresh_lo = if reuse_possible { self.prev.as_ref().unwrap().end_frame } else { start };
        (reuse_possible, fresh_lo)
    }

    /// Stage-pool seam, plan half: advance the stream's selection and
    /// change-score state for window [start, start+frames.len()) and
    /// detach each fresh frame's ViT encode as a standalone
    /// [`EncodeJob`] that may run on another thread against an
    /// executor replica. Returns `None` when the variant carries
    /// sequential cross-frame ViT state (Déjà Vu pixel reuse) — the
    /// caller must then fall back to the inline
    /// [`WindowEngine::prepare_window`].
    pub fn plan_encode(&mut self, frames: &[DecodedFrame], start: usize) -> Option<Vec<EncodeJob>> {
        if self.opts.vit_pixel_reuse.is_some() {
            return None;
        }
        let end = start + frames.len();
        self.ensure_selections(frames, start);
        self.update_change_scores(frames, start);
        let (_, fresh_lo) = self.fresh_range(start);
        Some(
            (fresh_lo..end)
                .map(|abs| EncodeJob {
                    frame: frames[abs - start].clone(),
                    abs_frame: abs,
                    selection: self.selections[abs].clone(),
                    layout: self.layout,
                    model: self.model.clone(),
                    spec: self.spec.clone(),
                    fused_preproc: self.opts.fused_preproc,
                })
                .collect(),
        )
    }

    /// Stage-pool seam, absorb half: fold pre-encoded frames (the
    /// outputs of this window's [`WindowEngine::plan_encode`] jobs,
    /// run elsewhere, in frame order) back into window preparation.
    /// Bit-identical to [`WindowEngine::prepare_window`] on the same
    /// window.
    pub fn prepare_window_preencoded(
        &mut self,
        frames: &[DecodedFrame],
        start: usize,
        frontend_times: StageTimes,
        encoded: Vec<EncodedFrame>,
    ) -> (BatchRequest, PendingWindow) {
        let end = start + frames.len();
        let mut times = frontend_times;
        let mut flops = 0u64;
        let mut flops_padded = 0u64;

        // Idempotent when plan_encode already consumed these frames.
        self.ensure_selections(frames, start);
        self.update_change_scores(frames, start);

        let (reuse_possible, fresh_lo) = self.fresh_range(start);
        debug_assert_eq!(
            encoded.len(),
            end - fresh_lo,
            "pre-encoded frames must cover exactly this window's fresh range"
        );

        let mut fresh_tokens: Vec<VisualToken> = Vec::new();
        let mut possible = 0usize;
        let mut retained = 0usize;
        for e in encoded {
            times.preprocess += e.preprocess_s;
            times.vit += e.vit_s;
            flops += e.flops;
            flops_padded += e.flops_padded;
            possible += self.layout.tokens_per_frame();
            retained += e.tokens.len();
            fresh_tokens.extend(e.tokens);
        }
        let pruned_ratio =
            if possible == 0 { 0.0 } else { 1.0 - retained as f64 / possible as f64 };

        let text_embs = self.text_embeddings(&mut times);

        if reuse_possible {
            self.incremental_prepare(start, end, fresh_tokens, &text_embs, times, flops, flops_padded, pruned_ratio)
        } else {
            self.full_prepare(start, end, fresh_tokens, &text_embs, times, flops, flops_padded, pruned_ratio)
        }
    }

    /// Consume a prefill launch's outputs: KV-state assembly, answer
    /// decoding, stream-state update. `outcome.exec_s` is this
    /// window's (possibly batch-amortized) share of the launch cost.
    pub fn finish_window(&mut self, pending: PendingWindow, outcome: BatchOutcome) -> WindowResult {
        let PendingWindow { start, end, mut times, mut flops, mut flops_padded, pruned_ratio, path } =
            pending;
        // The accuracy-proxy penalty of lossy backends is accounted at
        // the serving layer (per-backend stats); the engine consumes
        // the outputs as delivered.
        let BatchOutcome { outputs, exec_s, quant_penalty: _ } = outcome;
        times.llm_prefill += exec_s;
        let (l, h, hd) = (self.spec.llm_layers, self.spec.llm_heads, self.spec.head_dim);

        match path {
            PendingPath::Full { visual, text_len, t_real, bucket } => {
                flops += flops::prefill_full(&self.spec, t_real);
                flops_padded += flops::prefill_full(&self.spec, bucket);

                let last_hidden = outputs[0].as_f32().to_vec();
                let pooled = outputs[1].as_f32().to_vec();
                let logits = outputs[2].as_f32().to_vec();
                let k =
                    KvBlock::from_data(l, h, bucket, hd, outputs[3].as_f32().to_vec()).truncate(t_real);
                let v =
                    KvBlock::from_data(l, h, bucket, hd, outputs[4].as_f32().to_vec()).truncate(t_real);

                // Assemble records (sequence order).
                let mut tokens: Vec<TokenRecord> = Vec::with_capacity(t_real);
                for (i, tok) in visual.iter().enumerate() {
                    tokens.push(TokenRecord {
                        kind: TokenKind::Visual,
                        frame: tok.frame,
                        group: tok.group,
                        pos: i as i32,
                        is_iframe: tok.is_iframe,
                        emb: tok.emb.clone(),
                    });
                }
                for j in 0..text_len {
                    tokens.push(TokenRecord {
                        kind: TokenKind::Text,
                        frame: 0,
                        group: 0,
                        pos: (visual.len() + j) as i32,
                        is_iframe: false,
                        emb: Vec::new(),
                    });
                }

                let visual_count = visual.len();
                let state =
                    WindowState { start_frame: start, end_frame: end, tokens, k, v, compression_level: 0 };
                let decoded_ids =
                    self.decode_answer(&state, &logits, &mut times, &mut flops, &mut flops_padded);
                self.prev = Some(state);
                self.maybe_compress(start, end, &mut times);

                WindowResult {
                    start,
                    end,
                    last_hidden,
                    pooled,
                    logits,
                    decoded_ids,
                    seq_tokens: t_real,
                    visual_tokens: visual_count,
                    reused_tokens: 0,
                    refreshed_tokens: 0,
                    fresh_tokens: visual_count,
                    pruned_ratio,
                    flops,
                    flops_padded,
                    times,
                }
            }
            PendingPath::Incr {
                prev,
                seq,
                fresh,
                corrected_k,
                gathered_v,
                text_len,
                to_real,
                tn_real,
                tn_bucket,
                to_bucket,
                refreshed,
            } => {
                flops += flops::prefill_incr(&self.spec, tn_real, to_real);
                flops_padded += flops::prefill_incr(&self.spec, tn_bucket, to_bucket);

                let last_hidden = outputs[0].as_f32().to_vec();
                let pooled = outputs[1].as_f32().to_vec();
                let logits = outputs[2].as_f32().to_vec();
                let k_new = KvBlock::from_data(l, h, tn_bucket, hd, outputs[3].as_f32().to_vec())
                    .truncate(tn_real);
                let v_new = KvBlock::from_data(l, h, tn_bucket, hd, outputs[4].as_f32().to_vec())
                    .truncate(tn_real);

                // ---- assemble the new WindowState in sequence order ----
                let t_kvc1 = util::now();
                let t_total = seq.len();
                // Block-order K/V: [reused corrected ++ new]; build the
                // gather that reorders block order -> sequence order.
                let block_k = corrected_k.concat(&k_new);
                let block_v = gathered_v.concat(&v_new);
                let mut block_pos_of_seq = vec![0usize; t_total];
                {
                    let mut reused_cursor = 0usize;
                    let mut new_cursor = 0usize;
                    for (i, st) in seq.iter().enumerate() {
                        match st.src {
                            Src::Reused { .. } => {
                                block_pos_of_seq[i] = reused_cursor;
                                reused_cursor += 1;
                            }
                            _ => {
                                block_pos_of_seq[i] = to_real + new_cursor;
                                new_cursor += 1;
                            }
                        }
                    }
                }
                let k_seq = block_k.gather(&block_pos_of_seq);
                let v_seq = block_v.gather(&block_pos_of_seq);

                let mut tokens: Vec<TokenRecord> = Vec::with_capacity(t_total);
                for (i, st) in seq.iter().enumerate() {
                    let (kind, emb) = match st.src {
                        Src::Text { .. } => (TokenKind::Text, Vec::new()),
                        Src::Reused { prev_idx } | Src::Refresh { prev_idx } => {
                            (TokenKind::Visual, prev.tokens[prev_idx].emb.clone())
                        }
                        Src::Fresh { fresh_idx } => (TokenKind::Visual, fresh[fresh_idx].emb.clone()),
                    };
                    tokens.push(TokenRecord {
                        kind,
                        frame: st.frame,
                        group: st.group,
                        pos: i as i32,
                        is_iframe: st.is_iframe,
                        emb,
                    });
                }
                times.overhead_kvc += util::now() - t_kvc1;

                let visual_count = t_total - text_len;
                let fresh_count = fresh.len();
                let state = WindowState {
                    start_frame: start,
                    end_frame: end,
                    tokens,
                    k: k_seq,
                    v: v_seq,
                    compression_level: 0,
                };
                let decoded_ids =
                    self.decode_answer(&state, &logits, &mut times, &mut flops, &mut flops_padded);
                self.prev = Some(state);
                self.maybe_compress(start, end, &mut times);

                WindowResult {
                    start,
                    end,
                    last_hidden,
                    pooled,
                    logits,
                    decoded_ids,
                    seq_tokens: t_total,
                    visual_tokens: visual_count,
                    reused_tokens: to_real,
                    refreshed_tokens: refreshed,
                    fresh_tokens: fresh_count,
                    pruned_ratio,
                    flops,
                    flops_padded,
                    times,
                }
            }
        }
    }

    /// Build the full-prefill launch (first window, Recompute mode, or
    /// bucket-overflow fallback).
    #[allow(clippy::too_many_arguments)]
    fn full_prepare(
        &mut self,
        start: usize,
        end: usize,
        visual: Vec<VisualToken>,
        text_embs: &[Vec<f32>],
        times: StageTimes,
        flops: u64,
        flops_padded: u64,
        pruned_ratio: f64,
    ) -> (BatchRequest, PendingWindow) {
        let d = self.spec.llm_dim;
        let t_real = visual.len() + text_embs.len();
        let bucket = ModelSpec::pick_bucket(&self.spec.prefill_buckets, t_real);
        assert!(bucket >= t_real, "sequence {t_real} exceeds prefill buckets");

        let mut emb = vec![0.0f32; bucket * d];
        let mut pos = vec![0i32; bucket];
        let mut mask = vec![0.0f32; bucket];
        for (i, tok) in visual.iter().enumerate() {
            emb[i * d..(i + 1) * d].copy_from_slice(&tok.emb);
            pos[i] = i as i32;
            mask[i] = 1.0;
        }
        for (j, te) in text_embs.iter().enumerate() {
            let i = visual.len() + j;
            emb[i * d..(i + 1) * d].copy_from_slice(te);
            pos[i] = i as i32;
            mask[i] = 1.0;
        }

        let request = BatchRequest {
            model: self.model.clone(),
            artifact: format!("prefill_full_t{bucket}"),
            inputs: vec![
                Tensor::f32(&[bucket, d], emb),
                Tensor::i32(&[bucket], pos),
                Tensor::f32(&[bucket], mask),
                Tensor::scalar_i32(t_real as i32 - 1),
            ],
            // The engine has no session identity; the owning
            // StreamSession stamps its id before the request batches.
            stream: 0,
        };
        let pending = PendingWindow {
            start,
            end,
            times,
            flops,
            flops_padded,
            pruned_ratio,
            path: PendingPath::Full { visual, text_len: text_embs.len(), t_real, bucket },
        };
        (request, pending)
    }

    /// Build the incremental-prefill launch: reuse overlap KV, refresh
    /// per policy. Falls back to [`WindowEngine::full_prepare`] on
    /// bucket overflow.
    #[allow(clippy::too_many_arguments)]
    fn incremental_prepare(
        &mut self,
        start: usize,
        end: usize,
        fresh: Vec<VisualToken>,
        text_embs: &[Vec<f32>],
        mut times: StageTimes,
        mut flops: u64,
        flops_padded: u64,
        pruned_ratio: f64,
    ) -> (BatchRequest, PendingWindow) {
        let prev = self.prev.take().expect("incremental needs prev");
        let t_kvc0 = util::now();
        let policy = self.build_policy(&prev, start, end);
        let plan = plan_window(&prev, start, end, &policy);

        // ---- sequence assembly -------------------------------------
        // Overlap tokens (reused + refreshed) are already (frame,
        // group)-ascending in prev; fresh follows; text last.
        let mut seq: Vec<SeqTok> = Vec::new();
        {
            let mut ri = 0usize; // cursor into plan.reuse_idx
            let mut fi = 0usize; // cursor into plan.refresh_idx
            // merge the two ascending overlap lists
            while ri < plan.reuse_idx.len() || fi < plan.refresh_idx.len() {
                let take_reuse = match (plan.reuse_idx.get(ri), plan.refresh_idx.get(fi)) {
                    (Some(&a), Some(&b)) => a < b,
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                if take_reuse {
                    let i = plan.reuse_idx[ri];
                    let t = &prev.tokens[i];
                    seq.push(SeqTok {
                        src: Src::Reused { prev_idx: i },
                        frame: t.frame,
                        group: t.group,
                        is_iframe: t.is_iframe,
                    });
                    ri += 1;
                } else {
                    let i = plan.refresh_idx[fi];
                    let t = &prev.tokens[i];
                    seq.push(SeqTok {
                        src: Src::Refresh { prev_idx: i },
                        frame: t.frame,
                        group: t.group,
                        is_iframe: t.is_iframe,
                    });
                    fi += 1;
                }
            }
        }
        for (j, t) in fresh.iter().enumerate() {
            seq.push(SeqTok {
                src: Src::Fresh { fresh_idx: j },
                frame: t.frame,
                group: t.group,
                is_iframe: t.is_iframe,
            });
        }
        for j in 0..text_embs.len() {
            seq.push(SeqTok { src: Src::Text { text_idx: j }, frame: 0, group: 0, is_iframe: false });
        }

        // Positions = index in sequence. Split into old/new blocks.
        let mut reuse_prev_idx = Vec::new();
        let mut reuse_new_pos = Vec::new();
        let mut new_block: Vec<(usize, i32)> = Vec::new(); // (seq idx, pos)
        for (i, st) in seq.iter().enumerate() {
            match st.src {
                Src::Reused { prev_idx } => {
                    reuse_prev_idx.push(prev_idx);
                    reuse_new_pos.push(i as i32);
                }
                _ => new_block.push((i, i as i32)),
            }
        }
        let to_real = reuse_prev_idx.len();
        let tn_real = new_block.len();

        // Fallback: bucket overflow (e.g. huge stride) -> full prefill.
        let max_tn = *self.spec.incr_new_buckets.iter().max().unwrap();
        let max_to = *self.spec.incr_old_buckets.iter().max().unwrap();
        if tn_real > max_tn || to_real > max_to || to_real == 0 {
            times.overhead_kvc += util::now() - t_kvc0;
            // Rebuild the full visual token list (reused embeddings +
            // refreshed embeddings + fresh) and run the full path.
            let mut visual: Vec<VisualToken> = Vec::new();
            for st in &seq {
                match st.src {
                    Src::Reused { prev_idx } | Src::Refresh { prev_idx } => {
                        let t = &prev.tokens[prev_idx];
                        visual.push(VisualToken {
                            frame: t.frame,
                            group: t.group,
                            is_iframe: t.is_iframe,
                            emb: t.emb.clone(),
                        });
                    }
                    Src::Fresh { fresh_idx } => {
                        let t = &fresh[fresh_idx];
                        visual.push(VisualToken {
                            frame: t.frame,
                            group: t.group,
                            is_iframe: t.is_iframe,
                            emb: t.emb.clone(),
                        });
                    }
                    Src::Text { .. } => {}
                }
            }
            return self.full_prepare(start, end, visual, text_embs, times, flops, flops_padded, pruned_ratio);
        }

        // ---- gather + position-correct reused KV -------------------
        let gathered_k = prev.k.gather(&reuse_prev_idx);
        let gathered_v = prev.v.gather(&reuse_prev_idx);
        let deltas: Vec<i32> = reuse_prev_idx
            .iter()
            .zip(&reuse_new_pos)
            .map(|(&pi, &np)| np - prev.tokens[pi].pos)
            .collect();
        let mut corrected_k = gathered_k;
        rope::correct_keys(&mut corrected_k, &deltas, self.spec.rope_base);
        flops += flops::rope_correct(&self.spec, to_real);
        times.overhead_kvc += util::now() - t_kvc0;

        // ---- build the new block -----------------------------------
        let d = self.spec.llm_dim;
        let tn_bucket = ModelSpec::pick_bucket(&self.spec.incr_new_buckets, tn_real);
        let to_bucket = ModelSpec::pick_bucket(&self.spec.incr_old_buckets, to_real);
        let (old_k_pad, old_mask) = corrected_k.pad_to(to_bucket);
        let (old_v_pad, _) = gathered_v.pad_to(to_bucket);

        let mut new_emb = vec![0.0f32; tn_bucket * d];
        let mut new_pos = vec![0i32; tn_bucket];
        let mut new_mask = vec![0.0f32; tn_bucket];
        for (j, &(seq_idx, p)) in new_block.iter().enumerate() {
            let emb: &[f32] = match seq[seq_idx].src {
                Src::Refresh { prev_idx } => &prev.tokens[prev_idx].emb,
                Src::Fresh { fresh_idx } => &fresh[fresh_idx].emb,
                Src::Text { text_idx } => &text_embs[text_idx],
                Src::Reused { .. } => unreachable!(),
            };
            new_emb[j * d..(j + 1) * d].copy_from_slice(emb);
            new_pos[j] = p;
            new_mask[j] = 1.0;
        }

        let (l, h, hd) = (self.spec.llm_layers, self.spec.llm_heads, self.spec.head_dim);
        let request = BatchRequest {
            model: self.model.clone(),
            artifact: format!("prefill_incr_n{tn_bucket}_o{to_bucket}"),
            inputs: vec![
                Tensor::f32(&[tn_bucket, d], new_emb),
                Tensor::i32(&[tn_bucket], new_pos),
                Tensor::f32(&[tn_bucket], new_mask),
                // moved, not cloned: saves ~2-4 MB of memcpy per
                // window on the reuse hot path (EXPERIMENTS §Perf L3)
                Tensor::f32(&[l, h, to_bucket, hd], old_k_pad.data),
                Tensor::f32(&[l, h, to_bucket, hd], old_v_pad.data),
                Tensor::f32(&[to_bucket], old_mask),
                Tensor::scalar_i32(tn_real as i32 - 1),
            ],
            // Stamped with the session id by the coordinator, as above.
            stream: 0,
        };
        let refreshed = plan.refresh_idx.len();
        let pending = PendingWindow {
            start,
            end,
            times,
            flops,
            flops_padded,
            pruned_ratio,
            path: PendingPath::Incr {
                prev,
                seq,
                fresh,
                corrected_k,
                gathered_v,
                text_len: text_embs.len(),
                to_real,
                tn_real,
                tn_bucket,
                to_bucket,
                refreshed,
            },
        };
        (request, pending)
    }

    /// Turn the variant's RefreshSelect into a concrete policy for
    /// this window.
    fn build_policy(&self, prev: &WindowState, start: usize, end: usize) -> RefreshPolicy {
        let select = match &self.opts.kvc {
            KvcMode::Recompute => return RefreshPolicy::All,
            KvcMode::Reuse(s) => s.clone(),
        };
        match select {
            RefreshSelect::Anchors => RefreshPolicy::Anchors,
            RefreshSelect::None => RefreshPolicy::None,
            RefreshSelect::TopKByChange { frac } => {
                let overlap = prev.visual_in_range(start.max(prev.start_frame), end.min(prev.end_frame));
                let mut scored: Vec<(usize, f32)> = overlap
                    .iter()
                    .map(|&i| {
                        let t = &prev.tokens[i];
                        let s = self
                            .change_scores
                            .get(&(t.frame, t.group))
                            .copied()
                            .unwrap_or(0.0);
                        (i, s)
                    })
                    .collect();
                scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                let k = ((overlap.len() as f64) * frac).ceil() as usize;
                let mut chosen: Vec<usize> = scored.into_iter().take(k).map(|(i, _)| i).collect();
                chosen.sort_unstable();
                RefreshPolicy::Explicit(chosen)
            }
            RefreshSelect::FixedRatio { frac } => {
                let overlap = prev.visual_in_range(start.max(prev.start_frame), end.min(prev.end_frame));
                let k = ((overlap.len() as f64) * frac).ceil() as usize;
                let mut chosen = Vec::with_capacity(k);
                if k > 0 {
                    let step = (overlap.len().max(1) as f64 / k as f64).max(1.0);
                    let mut x = 0.0f64;
                    while chosen.len() < k && (x as usize) < overlap.len() {
                        chosen.push(overlap[x as usize]);
                        x += step;
                    }
                }
                RefreshPolicy::Explicit(chosen)
            }
        }
    }

    /// Maintain pixel-change scores per (frame, group) — the online
    /// signal CacheBlend-style selection uses (cost charged to
    /// overhead_kvc when that policy is active).
    fn update_change_scores(&mut self, frames: &[DecodedFrame], start: usize) {
        if !matches!(
            self.opts.kvc,
            KvcMode::Reuse(RefreshSelect::TopKByChange { .. })
        ) {
            return;
        }
        for (i, df) in frames.iter().enumerate() {
            let frame = &df.0;
            let abs = start + i;
            if self.change_scores.contains_key(&(abs, 0)) {
                continue;
            }
            let prev_frame: Option<&Frame> = if i > 0 {
                Some(&frames[i - 1].0)
            } else {
                None
            };
            for g in 0..self.layout.tokens_per_frame() {
                let score = match prev_frame {
                    Some(pf) => group_pixel_mad(&self.layout, frame, pf, g),
                    None => f32::MAX, // first frame: maximally changed
                };
                self.change_scores.insert((abs, g), score);
            }
        }
    }

    /// Greedy answer decoding through decode_step.
    fn decode_answer(
        &mut self,
        state: &WindowState,
        prefill_logits: &[f32],
        times: &mut StageTimes,
        flops: &mut u64,
        flops_padded: &mut u64,
    ) -> Vec<i32> {
        let n = self.opts.decode_tokens;
        if n == 0 {
            return Vec::new();
        }
        let slots = self.spec.decode_slots;
        let (l, h, hd) = (self.spec.llm_layers, self.spec.llm_heads, self.spec.head_dim);
        let t = state.seq_len();
        assert!(t + n <= slots, "decode slots too small");

        let (mut k_cache, mut cache_mask) = state.k.pad_to(slots);
        let (mut v_cache, _) = state.v.pad_to(slots);

        let mut ids = Vec::with_capacity(n);
        let mut next = argmax(prefill_logits) as i32;
        for step in 0..n {
            ids.push(next);
            if step + 1 == n {
                break; // last token needs no further forward pass
            }
            let pos = (t + step) as i32;
            let (outputs, exec_s) = self
                .exec
                .execute(
                    &self.model,
                    "decode_step",
                    &[
                        Tensor::scalar_i32(next),
                        Tensor::scalar_i32(pos),
                        Tensor::f32(&[l, h, slots, hd], k_cache.data.clone()),
                        Tensor::f32(&[l, h, slots, hd], v_cache.data.clone()),
                        Tensor::f32(&[slots], cache_mask.clone()),
                    ],
                )
                .expect("decode_step");
            times.llm_decode += exec_s;
            *flops += flops::decode_step(&self.spec, t + step);
            *flops_padded += flops::decode_step(&self.spec, slots);
            let logits = outputs[0].as_f32();
            next = argmax(logits) as i32;
            // Write the new KV entry into the cache slot.
            let k_new = outputs[1].as_f32();
            let v_new = outputs[2].as_f32();
            let slot = t + step;
            for li in 0..l {
                for hi in 0..h {
                    let off = k_cache.offset(li, hi, slot);
                    let src = (li * h + hi) * hd;
                    k_cache.data[off..off + hd].copy_from_slice(&k_new[src..src + hd]);
                    v_cache.data[off..off + hd].copy_from_slice(&v_new[src..src + hd]);
                }
            }
            cache_mask[slot] = 1.0;
        }
        ids
    }

    /// Cross-window compression step, run right after the window's
    /// state is retained: update the calm-window streak from the
    /// window's codec MV energy and, once the streak crosses the
    /// `compress_after` schedule, merge the retained KV 2:1 per level
    /// (4:1 total at level 2). Planning + merge cost is charged to
    /// `overhead_kvc`; the accuracy-proxy penalty accumulates like a
    /// lossy backend's `quant_penalty`, clamped to the configured cap.
    fn maybe_compress(&mut self, start: usize, end: usize, times: &mut StageTimes) {
        /// Penalty charged per compression level, scaled by the
        /// fraction of the sequence merged away in the step.
        const PENALTY_PER_LEVEL: f64 = 0.02;
        let Some(cfg) = self.compression else { return };
        let t0 = util::now();
        let lo = start.min(self.mv_energy.len());
        let hi = end.min(self.mv_energy.len());
        // Calm = the window's *mean* per-frame MV energy under the
        // threshold. Integer motion search makes per-frame energy
        // spiky (a slow object crossing a pixel boundary lights a few
        // macroblocks for one frame), so an every-frame test would
        // reset the streak on genuinely low-motion streams; the mean
        // rides over the spikes while high-motion windows still clear
        // the bar.
        let span = &self.mv_energy[lo..hi];
        let calm = !span.is_empty()
            && span.iter().sum::<f32>() / span.len() as f32 < cfg.calm_threshold;
        if calm {
            self.calm_windows += 1;
        } else {
            self.calm_windows = 0;
        }
        let target = cfg.policy.level_for(self.calm_windows);
        if let Some(state) = self.prev.as_mut() {
            // The level bumps every pass, so the loop terminates even
            // when a step bottoms out (one visual token per frame
            // left: nothing pairs, zero tokens merge).
            while state.compression_level < target {
                let bytes_before = state.bytes();
                let tokens_before = state.seq_len();
                let partition = compress_partition(state);
                let merged = state.merge_partition(&partition);
                if merged > 0 {
                    self.compress_stats.events += 1;
                    self.compress_stats.merged_tokens += merged as u64;
                    self.compress_stats.bytes_saved += (bytes_before - state.bytes()) as u64;
                    let frac = merged as f64 / tokens_before as f64;
                    let step = PENALTY_PER_LEVEL * state.compression_level as f64 * frac;
                    self.compress_stats.penalty =
                        (self.compress_stats.penalty + step).min(cfg.penalty_cap);
                }
            }
        }
        times.overhead_kvc += util::now() - t0;
    }

    pub fn prev_state(&self) -> Option<&WindowState> {
        self.prev.as_ref()
    }

    /// Drop the cached KV state (pool eviction).
    pub fn evict_kv(&mut self) {
        self.prev = None;
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Codec MV energy of one frame: mean macroblock MV magnitude with
/// sub-threshold magnitudes masked to zero — the same static-block
/// test the pruner applies per patch (eq. 1), aggregated per frame.
/// The mask matters: quarter-pel refinement on sensor noise parks
/// static-background MVs at ±0.25 px, and without it a perfectly calm
/// scene would read as uniform low-grade motion. I-frames (empty MV
/// list — intra frames carry no motion signal) are 0.
fn frame_mv_energy(meta: &FrameMeta, tau: f32) -> f32 {
    if meta.mvs.is_empty() {
        return 0.0;
    }
    meta.mvs
        .iter()
        .map(|m| {
            let mag = m.magnitude();
            if mag > tau {
                mag
            } else {
                0.0
            }
        })
        .sum::<f32>()
        / meta.mvs.len() as f32
}

/// Mean absolute pixel difference over one merge group's region.
fn group_pixel_mad(layout: &PatchLayout, a: &Frame, b: &Frame, group: usize) -> f32 {
    let mut sum = 0u32;
    let mut count = 0u32;
    for p in layout.group_patches(group) {
        let (px, py) = layout.patch_xy(p);
        for y in 0..layout.patch {
            for x in 0..layout.patch {
                let xx = px * layout.patch + x;
                let yy = py * layout.patch + y;
                sum += (a.at(xx, yy) as i32 - b.at(xx, yy) as i32).unsigned_abs();
                count += 1;
            }
        }
    }
    sum as f32 / count as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::MockEngine;
    use crate::video::{Corpus, CorpusConfig};

    fn test_frames(n: usize) -> Vec<DecodedFrame> {
        let corpus = Corpus::generate(CorpusConfig {
            videos: 1,
            frames_per_video: n,
            ..Default::default()
        });
        let frames = corpus.clips[0].frames.clone();
        let (bits, _) = crate::codec::encoder::encode_sequence(
            &frames,
            crate::codec::encoder::EncoderConfig::default(),
        );
        let mut dec = crate::codec::decoder::Decoder::new(bits).unwrap();
        dec.decode_all().unwrap().into_iter().map(std::sync::Arc::new).collect()
    }

    #[test]
    fn fullcomp_first_window() {
        let mock = MockEngine::new("m");
        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::fullcomp());
        let frames = test_frames(20);
        let r = eng.process_window(&frames, 0, StageTimes::default());
        assert_eq!(r.seq_tokens, 20 * 16 + 16);
        assert_eq!(r.reused_tokens, 0);
        assert_eq!(r.fresh_tokens, 320);
        assert!(r.flops > 0);
        assert_eq!(eng.prev_state().unwrap().seq_len(), r.seq_tokens);
    }

    #[test]
    fn codecflow_second_window_reuses() {
        let mock = MockEngine::new("m");
        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let all = test_frames(28);
        let r1 = eng.process_window(&all[0..20], 0, StageTimes::default());
        let r2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        assert!(r2.reused_tokens > 0, "r2 should reuse overlap KV");
        assert!(r2.fresh_tokens <= 4 * 16);
        assert!(r2.flops < r1.flops, "incremental should be cheaper");
        // window state invariants
        let st = eng.prev_state().unwrap();
        assert_eq!(st.start_frame, 4);
        assert_eq!(st.end_frame, 24);
        for (i, t) in st.tokens.iter().enumerate() {
            assert_eq!(t.pos, i as i32, "sequence order invariant");
        }
        // visual tokens ascend by (frame, group)
        let vis: Vec<_> = st
            .tokens
            .iter()
            .filter(|t| t.kind == TokenKind::Visual)
            .collect();
        for w in vis.windows(2) {
            assert!(
                (w[0].frame, w[0].group) < (w[1].frame, w[1].group),
                "(frame, group) ordering"
            );
        }
    }

    #[test]
    fn compression_shrinks_retained_kv_and_next_window_reuse() {
        let mock = MockEngine::new("m");
        let all = test_frames(28);
        let mut base = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let b1 = base.process_window(&all[0..20], 0, StageTimes::default());
        let base_bytes = base.prev_state().unwrap().bytes();
        let b2 = base.process_window(&all[4..24], 4, StageTimes::default());

        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        eng.set_compression(CompressionCfg {
            policy: CompressPolicy { after: 1, max_level: 1 },
            penalty_cap: 0.05,
            calm_threshold: f32::MAX, // every window calm: mechanics under test
        });
        let c1 = eng.process_window(&all[0..20], 0, StageTimes::default());
        assert_eq!(c1.logits, b1.logits, "compression acts only after the window completes");
        let st = eng.prev_state().unwrap();
        assert_eq!(st.compression_level, 1);
        assert!(st.bytes() < base_bytes, "retained KV must shrink");

        let c2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        assert!(c2.reused_tokens > 0, "compressed overlap is still reusable");
        assert!(
            c2.reused_tokens < b2.reused_tokens,
            "merged blocks mean fewer reused tokens ({} vs {})",
            c2.reused_tokens,
            b2.reused_tokens
        );
        let stats = eng.compress_stats();
        assert!(stats.events >= 1 && stats.merged_tokens > 0 && stats.bytes_saved > 0);
        assert!(stats.penalty > 0.0 && stats.penalty <= 0.05, "penalty bounded by the cap");
    }

    #[test]
    fn pruning_reduces_tokens() {
        let mock = MockEngine::new("m");
        let mut full = WindowEngine::new(&mock, "m", VariantOpts::fullcomp());
        let mut pruned = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let frames = test_frames(20);
        let rf = full.process_window(&frames, 0, StageTimes::default());
        let rp = pruned.process_window(&frames, 0, StageTimes::default());
        assert!(rp.visual_tokens < rf.visual_tokens, "{} vs {}", rp.visual_tokens, rf.visual_tokens);
        assert!(rp.pruned_ratio > 0.0);
    }

    #[test]
    fn recompute_mode_never_reuses() {
        let mock = MockEngine::new("m");
        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::fullcomp());
        let all = test_frames(28);
        let _ = eng.process_window(&all[0..20], 0, StageTimes::default());
        let r2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        assert_eq!(r2.reused_tokens, 0);
        assert_eq!(r2.fresh_tokens, 320);
    }

    #[test]
    fn decode_produces_ids() {
        let mock = MockEngine::new("m");
        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::fullcomp());
        let frames = test_frames(20);
        let r = eng.process_window(&frames, 0, StageTimes::default());
        assert_eq!(r.decoded_ids.len(), 2);
    }

    #[test]
    fn cacheblend_policy_refreshes_topk() {
        let mock = MockEngine::new("m");
        let mut opts = VariantOpts::fullcomp();
        opts.kvc = KvcMode::Reuse(RefreshSelect::TopKByChange { frac: 0.15 });
        let mut eng = WindowEngine::new(&mock, "m", opts);
        let all = test_frames(28);
        let _ = eng.process_window(&all[0..20], 0, StageTimes::default());
        let r2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        assert!(r2.refreshed_tokens > 0);
        let overlap_tokens = 16 * 16; // frames 4..20
        assert!(r2.refreshed_tokens <= (overlap_tokens as f64 * 0.15).ceil() as usize + 1);
        assert!(r2.reused_tokens > 0);
    }

    #[test]
    fn vlcache_fixed_ratio() {
        let mock = MockEngine::new("m");
        let mut opts = VariantOpts::fullcomp();
        opts.kvc = KvcMode::Reuse(RefreshSelect::FixedRatio { frac: 0.3 });
        let mut eng = WindowEngine::new(&mock, "m", opts);
        let all = test_frames(28);
        let _ = eng.process_window(&all[0..20], 0, StageTimes::default());
        let r2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        let overlap = 16 * 16;
        let expect = (overlap as f64 * 0.3).ceil() as usize;
        assert_eq!(r2.refreshed_tokens, expect);
    }

    #[test]
    fn dejavu_reuses_vit_outputs() {
        let mock = MockEngine::new("m");
        let mut opts = VariantOpts::fullcomp();
        opts.vit_pixel_reuse = Some(3.0);
        let mut eng = WindowEngine::new(&mock, "m", opts);
        let frames = test_frames(20);
        let r = eng.process_window(&frames, 0, StageTimes::default());
        // all LLM tokens still present (ViT-only optimization)
        assert_eq!(r.visual_tokens, 320);
        assert!(r.times.overhead_prune > 0.0);
    }

    #[test]
    fn batched_prefill_bit_for_bit_matches_unbatched() {
        // Two independent streams, served two ways: job-at-a-time
        // (process_window) vs prepare -> fused execute_batch ->
        // finish. Every deterministic output must be identical — the
        // invariant the batched shard loop relies on.
        let corpus = Corpus::generate(CorpusConfig {
            videos: 2,
            frames_per_video: 28,
            ..Default::default()
        });
        let streams: Vec<Vec<DecodedFrame>> = corpus
            .clips
            .iter()
            .map(|c| {
                let (bits, _) = crate::codec::encoder::encode_sequence(
                    &c.frames,
                    crate::codec::encoder::EncoderConfig::default(),
                );
                crate::codec::decoder::Decoder::new(bits)
                    .unwrap()
                    .decode_all()
                    .unwrap()
                    .into_iter()
                    .map(std::sync::Arc::new)
                    .collect()
            })
            .collect();

        let mock = MockEngine::new("m");
        let mut solo: Vec<WindowEngine> = (0..2)
            .map(|_| WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0)))
            .collect();
        let mut batched: Vec<WindowEngine> = (0..2)
            .map(|_| WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0)))
            .collect();

        // Window 0 exercises the full-prefill path, window 1 the
        // incremental (KV-reuse) path.
        for (start, end) in [(0usize, 20usize), (4, 24)] {
            let solo_results: Vec<WindowResult> = solo
                .iter_mut()
                .zip(&streams)
                .map(|(e, f)| e.process_window(&f[start..end], start, StageTimes::default()))
                .collect();
            let mut reqs = Vec::new();
            let mut pends = Vec::new();
            for (e, f) in batched.iter_mut().zip(&streams) {
                let (req, pend) = e.prepare_window(&f[start..end], start, StageTimes::default());
                reqs.push(req);
                pends.push(pend);
            }
            let outcomes = mock.execute_batch(&reqs).unwrap();
            for (((e, pend), outcome), want) in
                batched.iter_mut().zip(pends).zip(outcomes).zip(&solo_results)
            {
                let got = e.finish_window(pend, outcome);
                assert_eq!(got.logits, want.logits);
                assert_eq!(got.pooled, want.pooled);
                assert_eq!(got.decoded_ids, want.decoded_ids);
                assert_eq!(got.seq_tokens, want.seq_tokens);
                assert_eq!(got.flops, want.flops);
                assert_eq!(got.flops_padded, want.flops_padded);
                assert_eq!(got.reused_tokens, want.reused_tokens);
                assert_eq!(got.fresh_tokens, want.fresh_tokens);
            }
        }
    }

    #[test]
    fn preencoded_path_bit_for_bit_matches_prepare_window() {
        // The stage-pool seam: plan_encode -> EncodeJob::run (here on
        // the same thread, against the same executor — replicas are
        // deterministic) -> prepare_window_preencoded must reproduce
        // prepare_window exactly, on both the full-prefill window and
        // the incremental (KV-reuse) window.
        let mock = MockEngine::new("m");
        let mut inline = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let mut staged = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let all = test_frames(28);

        for (start, end) in [(0usize, 20usize), (4, 24)] {
            let (req_a, pend_a) =
                inline.prepare_window(&all[start..end], start, StageTimes::default());

            let jobs = staged
                .plan_encode(&all[start..end], start)
                .expect("non-Déjà-Vu variants detach");
            let encoded: Vec<EncodedFrame> = jobs.iter().map(|j| j.run(&mock)).collect();
            let (req_b, pend_b) = staged.prepare_window_preencoded(
                &all[start..end],
                start,
                StageTimes::default(),
                encoded,
            );

            assert_eq!(req_a.model, req_b.model);
            assert_eq!(req_a.artifact, req_b.artifact);
            assert_eq!(req_a.inputs, req_b.inputs, "prefill inputs must match bit-for-bit");

            let out_a = mock.execute_batch(std::slice::from_ref(&req_a)).unwrap().remove(0);
            let out_b = mock.execute_batch(std::slice::from_ref(&req_b)).unwrap().remove(0);
            let ra = inline.finish_window(pend_a, out_a);
            let rb = staged.finish_window(pend_b, out_b);
            assert_eq!(ra.logits, rb.logits);
            assert_eq!(ra.pooled, rb.pooled);
            assert_eq!(ra.decoded_ids, rb.decoded_ids);
            assert_eq!(ra.seq_tokens, rb.seq_tokens);
            assert_eq!(ra.flops, rb.flops);
            assert_eq!(ra.flops_padded, rb.flops_padded);
            assert_eq!(ra.reused_tokens, rb.reused_tokens);
            assert_eq!(ra.fresh_tokens, rb.fresh_tokens);
            assert_eq!(ra.pruned_ratio, rb.pruned_ratio);
        }
    }

    #[test]
    fn dejavu_variant_declines_to_detach_encode() {
        let mock = MockEngine::new("m");
        let mut opts = VariantOpts::fullcomp();
        opts.vit_pixel_reuse = Some(3.0);
        let mut eng = WindowEngine::new(&mock, "m", opts);
        let frames = test_frames(20);
        assert!(eng.plan_encode(&frames, 0).is_none());
        // The inline path still works after the declined plan.
        let r = eng.process_window(&frames, 0, StageTimes::default());
        assert_eq!(r.visual_tokens, 320);
    }

    #[test]
    fn eviction_falls_back_to_full() {
        let mock = MockEngine::new("m");
        let mut eng = WindowEngine::new(&mock, "m", VariantOpts::codecflow(0.25, 0.0));
        let all = test_frames(28);
        let _ = eng.process_window(&all[0..20], 0, StageTimes::default());
        eng.evict_kv();
        let r2 = eng.process_window(&all[4..24], 4, StageTimes::default());
        assert_eq!(r2.reused_tokens, 0, "evicted cache cannot be reused");
    }
}
