//! Uplink simulator: edge camera -> cloud transmission (DESIGN.md §3).
//!
//! Transmission latency is a pure function of payload size and the
//! link model; the paper's 5 Mbps representative edge uplink (§2.2,
//! [68]) is the default. Models serialization delay + propagation RTT
//! + simple pacing; enough to reproduce the Fig 3 "Trans" share and
//! the Fig 11 transmission reduction, which are driven entirely by the
//! JPEG-vs-bitstream size ratio.

/// Link model.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Uplink bandwidth, bits per second.
    pub bandwidth_bps: f64,
    /// One-way propagation delay, seconds.
    pub propagation_s: f64,
    /// Per-message protocol overhead, bytes (headers/framing).
    pub overhead_bytes: usize,
}

impl Default for Link {
    fn default() -> Self {
        // Paper §2.2: representative 5 Mbps edge uplink; metro-edge
        // propagation (2 ms) so serialization delay — the thing the
        // compressed bitstream reduces — dominates, as in the paper's
        // per-frame-JPEG setting.
        Link { bandwidth_bps: 5e6, propagation_s: 0.002, overhead_bytes: 64 }
    }
}

impl Link {
    pub fn mbps(bandwidth_mbps: f64) -> Link {
        Link { bandwidth_bps: bandwidth_mbps * 1e6, ..Default::default() }
    }

    /// Seconds to deliver one message of `payload_bytes`.
    pub fn transmit_s(&self, payload_bytes: usize) -> f64 {
        let bits = ((payload_bytes + self.overhead_bytes) * 8) as f64;
        bits / self.bandwidth_bps + self.propagation_s
    }

    /// Seconds to deliver a batch of messages back-to-back (pipelined:
    /// pay propagation once, serialization for all).
    pub fn transmit_batch_s(&self, payload_bytes: &[usize]) -> f64 {
        let bits: f64 = payload_bytes
            .iter()
            .map(|&b| ((b + self.overhead_bytes) * 8) as f64)
            .sum();
        bits / self.bandwidth_bps + self.propagation_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_scales_with_size() {
        let l = Link::mbps(5.0);
        let t1 = l.transmit_s(10_000);
        let t2 = l.transmit_s(20_000);
        assert!(t2 > t1);
        // 10 KB at 5 Mbps ~ 16 ms + prop
        assert!((t1 - (10_064.0 * 8.0 / 5e6 + 0.002)).abs() < 1e-9);
    }

    #[test]
    fn batch_cheaper_than_individual() {
        let l = Link::default();
        let sizes = [5000usize; 10];
        let batch = l.transmit_batch_s(&sizes);
        let indiv: f64 = sizes.iter().map(|&s| l.transmit_s(s)).sum();
        assert!(batch < indiv);
    }

    #[test]
    fn faster_link_faster() {
        assert!(Link::mbps(50.0).transmit_s(100_000) < Link::mbps(5.0).transmit_s(100_000));
    }
}
