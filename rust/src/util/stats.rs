//! Descriptive statistics and CDFs for experiment reporting.

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Percentile (linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Empirical CDF evaluated at the given grid points.
pub fn cdf_at(xs: &[f64], grid: &[f64]) -> Vec<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    grid.iter()
        .map(|&g| {
            let cnt = sorted.partition_point(|&x| x <= g);
            cnt as f64 / sorted.len().max(1) as f64
        })
        .collect()
}

/// Precision / recall / F1 from confusion counts.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PrF1 {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl PrF1 {
    pub fn add(&mut self, predicted: bool, actual: bool) {
        match (predicted, actual) {
            (true, true) => self.tp += 1,
            (true, false) => self.fp += 1,
            (false, false) => self.tn += 1,
            (false, true) => self.fn_ += 1,
        }
    }

    pub fn precision(&self) -> f64 {
        let d = self.tp + self.fp;
        if d == 0 { 0.0 } else { self.tp as f64 / d as f64 }
    }

    pub fn recall(&self) -> f64 {
        let d = self.tp + self.fn_;
        if d == 0 { 0.0 } else { self.tp as f64 / d as f64 }
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 { 0.0 } else { 2.0 * p * r / (p + r) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [1.0, 2.0, 2.0, 3.0, 9.0];
        let grid = [0.0, 1.0, 2.0, 3.0, 10.0];
        let c = cdf_at(&xs, &grid);
        assert_eq!(c[0], 0.0);
        assert_eq!(*c.last().unwrap(), 1.0);
        for w in c.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn f1_perfect_and_worst() {
        let mut m = PrF1::default();
        m.add(true, true);
        m.add(false, false);
        assert_eq!(m.f1(), 1.0);
        let mut w = PrF1::default();
        w.add(false, true);
        w.add(true, false);
        assert_eq!(w.f1(), 0.0);
    }

    #[test]
    fn f1_mixed() {
        let mut m = PrF1::default();
        for _ in 0..8 { m.add(true, true); }
        for _ in 0..2 { m.add(true, false); }
        for _ in 0..2 { m.add(false, true); }
        assert!((m.precision() - 0.8).abs() < 1e-12);
        assert!((m.recall() - 0.8).abs() < 1e-12);
        assert!((m.f1() - 0.8).abs() < 1e-12);
    }
}
