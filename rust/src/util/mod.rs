//! Support utilities: PRNG, statistics, micro-benchmark harness,
//! property-testing helper, ascii tables/plots, thread pool.
//!
//! `criterion` and `proptest` are not available in the offline crate
//! set (DESIGN.md §9); [`bench`] and [`quick`] are the purpose-built
//! replacements used by `benches/` and the test suite.

pub mod bench;
pub mod plot;
pub mod prng;
pub mod quick;
pub mod stats;
pub mod table;
pub mod threadpool;

/// Wall-clock seconds since an arbitrary epoch (monotonic).
pub fn now() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_secs_f64()
}

/// Incremental FNV-1a over 64-bit lanes — the crate's one cheap
/// fingerprint primitive, shared by stream→shard placement
/// (`coordinator::shard::assign_shard`), the mock executor's
/// deterministic output seeding, and the serving result digest.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }

    #[inline]
    pub fn mix(&mut self, b: u64) {
        self.0 ^= b;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::Fnv64;

    #[test]
    fn fnv64_is_order_and_value_sensitive() {
        let digest = |xs: &[u64]| {
            let mut h = Fnv64::new();
            for &x in xs {
                h.mix(x);
            }
            h.value()
        };
        assert_eq!(digest(&[1, 2, 3]), digest(&[1, 2, 3]));
        assert_ne!(digest(&[1, 2, 3]), digest(&[3, 2, 1]));
        assert_ne!(digest(&[1, 2, 3]), digest(&[1, 2, 4]));
        assert_ne!(digest(&[]), digest(&[0]));
    }
}
