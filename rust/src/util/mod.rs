//! Support utilities: PRNG, statistics, micro-benchmark harness,
//! property-testing helper, ascii tables/plots, thread pool.
//!
//! `criterion` and `proptest` are not available in the offline crate
//! set (DESIGN.md §9); [`bench`] and [`quick`] are the purpose-built
//! replacements used by `benches/` and the test suite.

pub mod bench;
pub mod plot;
pub mod prng;
pub mod quick;
pub mod stats;
pub mod table;
pub mod threadpool;

/// Wall-clock seconds since an arbitrary epoch (monotonic).
pub fn now() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_secs_f64()
}

/// Print `warning: {msg}` to stderr the first time `key` is seen in
/// this process; later calls with the same key are silent. One shared
/// registry replaces the per-site `std::sync::Once` statics the
/// dispatcher's no-op warnings used to carry. Returns whether the
/// message was emitted, so callers (and tests) can observe the dedup
/// without scraping stderr.
pub fn warn_once(key: &str, msg: &str) -> bool {
    use std::collections::HashSet;
    use std::sync::Mutex;
    // `Mutex::new(None)` is const, so no OnceLock indirection needed;
    // the set is allocated lazily on the first warning.
    static WARNED: Mutex<Option<HashSet<String>>> = Mutex::new(None);
    let mut guard = WARNED.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let seen = guard.get_or_insert_with(HashSet::new);
    if seen.insert(key.to_string()) {
        eprintln!("warning: {msg}");
        true
    } else {
        false
    }
}

/// Incremental FNV-1a over 64-bit lanes — the crate's one cheap
/// fingerprint primitive, shared by stream→shard placement
/// (`coordinator::shard::assign_shard`), the mock executor's
/// deterministic output seeding, and the serving result digest.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }

    #[inline]
    pub fn mix(&mut self, b: u64) {
        self.0 ^= b;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    pub fn value(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::{warn_once, Fnv64};

    #[test]
    fn warn_once_emits_once_per_key() {
        // Keys are namespaced to this test so parallel test binaries
        // sharing the process-wide registry cannot race it.
        assert!(warn_once("test-warn-once-a", "first a"));
        assert!(!warn_once("test-warn-once-a", "second a is suppressed"));
        assert!(!warn_once("test-warn-once-a", "so is a different message"));
        assert!(warn_once("test-warn-once-b", "a fresh key emits"));
        assert!(!warn_once("test-warn-once-b", "once"));
    }

    #[test]
    fn fnv64_is_order_and_value_sensitive() {
        let digest = |xs: &[u64]| {
            let mut h = Fnv64::new();
            for &x in xs {
                h.mix(x);
            }
            h.value()
        };
        assert_eq!(digest(&[1, 2, 3]), digest(&[1, 2, 3]));
        assert_ne!(digest(&[1, 2, 3]), digest(&[3, 2, 1]));
        assert_ne!(digest(&[1, 2, 3]), digest(&[1, 2, 4]));
        assert_ne!(digest(&[]), digest(&[0]));
    }
}
