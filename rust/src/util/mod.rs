//! Support utilities: PRNG, statistics, micro-benchmark harness,
//! property-testing helper, ascii tables/plots, thread pool.
//!
//! `criterion` and `proptest` are not available in the offline crate
//! set (DESIGN.md §9); [`bench`] and [`quick`] are the purpose-built
//! replacements used by `benches/` and the test suite.

pub mod bench;
pub mod plot;
pub mod prng;
pub mod quick;
pub mod stats;
pub mod table;
pub mod threadpool;

/// Wall-clock seconds since an arbitrary epoch (monotonic).
pub fn now() -> f64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_secs_f64()
}
