//! Mini property-testing helper (proptest replacement, DESIGN.md §9).
//!
//! `quick::check(seed, cases, |g| { ... })` runs a property over many
//! seeded random inputs; on failure it reports the case seed so the
//! exact input can be replayed with `quick::replay`.

use super::prng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.range_f64(lo as f64, hi as f64) as f32).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `prop` over `cases` random inputs; panics with the failing case
/// seed on the first violation.
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut prop: F) {
    let mut meta = Rng::new(seed);
    for i in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Re-run a property on the exact input of a previously failing case.
pub fn replay<F: FnMut(&mut Gen)>(case_seed: u64, mut prop: F) {
    let mut g = Gen { rng: Rng::new(case_seed), case_seed };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 100, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert!(a + b >= a);
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(2, 100, |g| {
            let x = g.usize_in(0, 10);
            assert!(x < 10, "hit the edge");
        });
    }

    #[test]
    fn ranges_respected() {
        check(3, 200, |g| {
            let x = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&x));
            let f = g.f64_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
        });
    }
}
