//! Fixed-size thread pool (tokio replacement for the serving loop,
//! DESIGN.md §9). The request path only needs fan-out/fan-in over
//! blocking PJRT executions, which a channel-fed pool models exactly.
//!
//! This is the execution substrate of the sharded serving layer
//! ([`crate::coordinator::dispatch`]): each shard runs as one pool job,
//! so windows from different shards execute concurrently. Jobs are
//! panic-isolated — a panicking job is caught, reported through its
//! [`JobHandle`], and never takes a worker thread down with it.
//!
//! [`Lane`] is the second substrate: a *dedicated* worker thread that
//! owns a piece of state (for the serving layer, an executor replica —
//! see [`crate::runtime::replica::LaunchedExecutor`]) and consumes
//! jobs from a **bounded** FIFO queue. Where the pool fans independent
//! jobs across threads, a lane serializes jobs against one owned
//! resource and pushes back on producers when it falls behind:
//! [`Lane::spawn`] blocks once `capacity` jobs are queued, so a fast
//! producer stalls instead of queueing unboundedly.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// A shared-queue thread pool. Submitted jobs run FIFO across workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

/// Fan-in handle for one [`ThreadPool::spawn`]ed job.
///
/// `join` blocks until the job finishes; a panic inside the job is
/// caught and surfaced as `Err(message)` instead of poisoning the pool.
pub struct JobHandle<R> {
    rx: mpsc::Receiver<Result<R, String>>,
}

impl<R> JobHandle<R> {
    /// Block until the job completes; `Err` carries the panic message.
    pub fn join(self) -> Result<R, String> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err("worker disconnected before completing the job".to_string()))
    }
}

/// Join a batch of handles, preserving submission order.
pub fn join_all<R>(handles: Vec<JobHandle<R>>) -> Vec<Result<R, String>> {
    handles.into_iter().map(|h| h.join()).collect()
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker job panicked".to_string()
    }
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cf-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            // Panic isolation: a job that panics must not
                            // kill the worker — spawn() has already
                            // captured the payload for its JobHandle.
                            Ok(Msg::Run(job)) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Submit a job and get a [`JobHandle`] to fan its result back in.
    pub fn spawn<F, R>(&self, f: F) -> JobHandle<R>
    where
        F: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            let result = catch_unwind(AssertUnwindSafe(f)).map_err(panic_message);
            let _ = tx.send(result);
        });
        JobHandle { rx }
    }

    /// Map `f` over items in parallel, preserving order. Panics if any
    /// job panicked — use [`ThreadPool::try_map`] to recover instead.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.try_map(items, f)
            .into_iter()
            .map(|r| r.expect("pool job panicked"))
            .collect()
    }

    /// Map `f` over items in parallel, preserving order; each result is
    /// `Err(panic message)` if that item's job panicked.
    pub fn try_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<JobHandle<R>> = items
            .into_iter()
            .map(|item| {
                let f = Arc::clone(&f);
                self.spawn(move || f(item))
            })
            .collect();
        join_all(handles)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

type LaneJob<S> = Box<dyn FnOnce(&mut S) + Send + 'static>;

/// A dedicated worker thread owning a state value `S`, fed by a
/// **bounded** FIFO queue of jobs `FnOnce(&mut S) -> R`.
///
/// The state is moved onto the lane thread at construction and never
/// leaves it — callers only reach it through submitted closures, so
/// `S` needs `Send` but never `Sync`. This is the ownership model the
/// wall-clock pipelined serving layer uses for executors: the shard
/// thread prepares batches while the lane thread, which owns the
/// executor, runs them ([`crate::runtime::replica::LaunchedExecutor`]).
///
/// Backpressure: [`Lane::spawn`] blocks once `capacity` jobs are
/// queued (bounded `sync_channel`), so a producer that outruns the
/// lane stalls instead of queueing unboundedly. Panics inside a job
/// are caught and surfaced through the job's [`JobHandle`] — the lane
/// thread survives and keeps draining (the state is reused as-is, the
/// same `AssertUnwindSafe` contract the pool uses).
pub struct Lane<S> {
    tx: Option<mpsc::SyncSender<LaneJob<S>>>,
    handle: Option<thread::JoinHandle<()>>,
    capacity: usize,
}

impl<S: Send + 'static> Lane<S> {
    /// Spawn the lane thread, moving `state` onto it. `capacity` is
    /// the bounded queue depth (must be >= 1): the number of jobs that
    /// may wait unserviced before `spawn` blocks the producer.
    pub fn new(name: &str, capacity: usize, state: S) -> Lane<S> {
        assert!(capacity > 0, "lane queue must hold at least one job");
        let (tx, rx) = mpsc::sync_channel::<LaneJob<S>>(capacity);
        let handle = thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                let mut state = state;
                while let Ok(job) = rx.recv() {
                    job(&mut state);
                }
            })
            .expect("spawn lane thread");
        Lane { tx: Some(tx), handle: Some(handle), capacity }
    }

    /// Bounded queue depth this lane was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Submit a job against the lane's state; **blocks** while the
    /// queue holds `capacity` unserviced jobs (backpressure). The
    /// returned handle fans the result back in; a panic inside the job
    /// surfaces as `Err(message)` there.
    pub fn spawn<F, R>(&self, f: F) -> JobHandle<R>
    where
        F: FnOnce(&mut S) -> R + Send + 'static,
        R: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        let job: LaneJob<S> = Box::new(move |state| {
            let result = catch_unwind(AssertUnwindSafe(|| f(state))).map_err(panic_message);
            let _ = tx.send(result);
        });
        self.tx
            .as_ref()
            .expect("lane alive")
            .send(job)
            .expect("lane thread alive");
        JobHandle { rx }
    }
}

impl<S> Drop for Lane<S> {
    fn drop(&mut self) {
        // Closing the channel ends the drain loop after queued jobs
        // finish; join so the owned state is dropped before we return.
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool);
    }

    #[test]
    fn spawn_joins_result() {
        let pool = ThreadPool::new(2);
        let h = pool.spawn(|| 6 * 7);
        assert_eq!(h.join(), Ok(42));
    }

    #[test]
    fn panicking_job_reports_error_and_pool_survives() {
        let pool = ThreadPool::new(1);
        let bad = pool.spawn(|| -> usize { panic!("boom {}", 1 + 1) });
        let err = bad.join().unwrap_err();
        assert!(err.contains("boom"), "got: {err}");
        // The single worker must still be alive to run the next job.
        let good = pool.spawn(|| 7usize);
        assert_eq!(good.join(), Ok(7));
    }

    #[test]
    fn try_map_isolates_panics_per_item() {
        let pool = ThreadPool::new(3);
        let out = pool.try_map((0..10u32).collect(), |x| {
            if x % 4 == 0 {
                panic!("bad item");
            }
            x * 10
        });
        for (i, r) in out.iter().enumerate() {
            if i % 4 == 0 {
                assert!(r.is_err(), "item {i} should have panicked");
            } else {
                assert_eq!(r.as_ref().unwrap(), &(i as u32 * 10));
            }
        }
    }

    #[test]
    fn join_all_preserves_order() {
        let pool = ThreadPool::new(4);
        let handles: Vec<_> = (0..20usize).map(|i| pool.spawn(move || i)).collect();
        let out = join_all(handles);
        for (i, r) in out.into_iter().enumerate() {
            assert_eq!(r, Ok(i));
        }
    }

    #[test]
    fn lane_owns_state_and_runs_jobs_fifo() {
        let lane = Lane::new("t-lane", 4, Vec::<usize>::new());
        let handles: Vec<_> = (0..10usize)
            .map(|i| {
                lane.spawn(move |log: &mut Vec<usize>| {
                    log.push(i);
                    i * 2
                })
            })
            .collect();
        for (i, r) in join_all(handles).into_iter().enumerate() {
            assert_eq!(r, Ok(i * 2));
        }
        // State persists across jobs, in submission order.
        let log = lane.spawn(|log: &mut Vec<usize>| log.clone()).join().unwrap();
        assert_eq!(log, (0..10).collect::<Vec<usize>>());
    }

    #[test]
    fn lane_panic_reported_and_state_survives() {
        let lane = Lane::new("t-lane", 2, 0usize);
        lane.spawn(|n| *n += 1).join().unwrap();
        let err = lane
            .spawn(|_: &mut usize| -> usize { panic!("lane job fault") })
            .join()
            .unwrap_err();
        assert!(err.contains("lane job fault"), "got: {err}");
        // The lane thread and its state are still alive.
        assert_eq!(lane.spawn(|n| *n + 41).join(), Ok(42));
    }

    #[test]
    fn lane_bounded_queue_blocks_producer_at_exact_depth() {
        // The backpressure contract, pinned exactly: with the worker
        // wedged on a gated job that has already LEFT the queue, the
        // queue holds precisely `capacity` unserviced jobs — a
        // producer completes exactly `capacity` submissions and
        // stalls on number `capacity + 1`, for every capacity. Work
        // never queues unboundedly, and never less than the bound
        // either (the stage pools size their rings on this).
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        use std::sync::Condvar;
        use std::time::Duration;

        for capacity in [1usize, 2, 4] {
            let lane = Arc::new(Lane::new("t-lane", capacity, ()));
            let gate = Arc::new((Mutex::new(false), Condvar::new()));
            let started = Arc::new(AtomicBool::new(false));
            let submitted = Arc::new(AtomicUsize::new(0));

            let g = Arc::clone(&gate);
            let s = Arc::clone(&started);
            let blocker = lane.spawn(move |_| {
                // Signal *after* dequeue: from here on, all `capacity`
                // queue slots are observably free.
                s.store(true, Ordering::SeqCst);
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while !started.load(Ordering::SeqCst) {
                assert!(std::time::Instant::now() < deadline, "gated job never started");
                thread::sleep(Duration::from_millis(1));
            }

            let producer = {
                let lane = Arc::clone(&lane);
                let submitted = Arc::clone(&submitted);
                thread::spawn(move || {
                    let handles: Vec<_> = (0..capacity + 2)
                        .map(|i| {
                            let h = lane.spawn(move |_| i);
                            submitted.fetch_add(1, Ordering::SeqCst);
                            h
                        })
                        .collect();
                    join_all(handles)
                })
            };

            // The producer must reach the bound — and then not pass it.
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            while submitted.load(Ordering::SeqCst) < capacity
                && std::time::Instant::now() < deadline
            {
                thread::sleep(Duration::from_millis(1));
            }
            thread::sleep(Duration::from_millis(50));
            let stalled_at = submitted.load(Ordering::SeqCst);
            assert_eq!(
                stalled_at, capacity,
                "producer should stall at exactly the {capacity}-deep bound"
            );

            // Open the gate: the lane drains and the producer completes.
            {
                let (lock, cv) = &*gate;
                *lock.lock().unwrap() = true;
                cv.notify_all();
            }
            blocker.join().unwrap();
            let results = producer.join().unwrap();
            assert_eq!(submitted.load(Ordering::SeqCst), capacity + 2);
            for (i, r) in results.into_iter().enumerate() {
                assert_eq!(r, Ok(i));
            }
        }
    }

    #[test]
    fn lane_concurrent_producers_keep_per_producer_fifo_despite_panics() {
        // Property test for the stage-pool usage pattern: several
        // producers share one bounded lane, some jobs panic.
        // 1. Per-producer FIFO — the lane runs each producer's jobs in
        //    that producer's submission order (its spawn calls are
        //    totally ordered; the bounded channel preserves them).
        // 2. Panic isolation — a faulty job errors only its own
        //    handle; the lane thread and its state survive every
        //    fault and later jobs (from any producer) still run.
        const PRODUCERS: usize = 4;
        const JOBS: usize = 25;
        let faulty = |i: usize| i % 7 == 3;

        let lane = Arc::new(Lane::new("t-lane", 3, Vec::<(usize, usize)>::new()));
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let lane = Arc::clone(&lane);
                thread::spawn(move || {
                    let handles: Vec<_> = (0..JOBS)
                        .map(|i| {
                            lane.spawn(move |log: &mut Vec<(usize, usize)>| {
                                if i % 7 == 3 {
                                    panic!("fault p{p} i{i}");
                                }
                                log.push((p, i));
                                (p, i)
                            })
                        })
                        .collect();
                    join_all(handles)
                })
            })
            .collect();

        for (p, t) in producers.into_iter().enumerate() {
            for (i, r) in t.join().unwrap().into_iter().enumerate() {
                if faulty(i) {
                    let err = r.unwrap_err();
                    assert!(
                        err.contains(&format!("fault p{p} i{i}")),
                        "fault must surface on its own handle, got: {err}"
                    );
                } else {
                    assert_eq!(r, Ok((p, i)));
                }
            }
        }

        // The lane thread and its state survived all faults.
        let log = lane.spawn(|log: &mut Vec<(usize, usize)>| log.clone()).join().unwrap();
        let expect: Vec<usize> = (0..JOBS).filter(|&i| !faulty(i)).collect();
        for p in 0..PRODUCERS {
            let seq: Vec<usize> =
                log.iter().filter(|&&(q, _)| q == p).map(|&(_, i)| i).collect();
            assert_eq!(seq, expect, "producer {p} jobs must run in its submission order");
        }
        assert_eq!(log.len(), PRODUCERS * expect.len(), "faulty jobs never mutate state");
    }
}
