//! Fixed-size thread pool (tokio replacement for the serving loop,
//! DESIGN.md §9). The request path only needs fan-out/fan-in over
//! blocking PJRT executions, which a channel-fed pool models exactly.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// A shared-queue thread pool. Submitted jobs run FIFO across workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cf-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Map `f` over items in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool);
    }
}
