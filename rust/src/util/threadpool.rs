//! Fixed-size thread pool (tokio replacement for the serving loop,
//! DESIGN.md §9). The request path only needs fan-out/fan-in over
//! blocking PJRT executions, which a channel-fed pool models exactly.
//!
//! This is the execution substrate of the sharded serving layer
//! ([`crate::coordinator::dispatch`]): each shard runs as one pool job,
//! so windows from different shards execute concurrently. Jobs are
//! panic-isolated — a panicking job is caught, reported through its
//! [`JobHandle`], and never takes a worker thread down with it.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// A shared-queue thread pool. Submitted jobs run FIFO across workers.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

/// Fan-in handle for one [`ThreadPool::spawn`]ed job.
///
/// `join` blocks until the job finishes; a panic inside the job is
/// caught and surfaced as `Err(message)` instead of poisoning the pool.
pub struct JobHandle<R> {
    rx: mpsc::Receiver<Result<R, String>>,
}

impl<R> JobHandle<R> {
    /// Block until the job completes; `Err` carries the panic message.
    pub fn join(self) -> Result<R, String> {
        self.rx
            .recv()
            .unwrap_or_else(|_| Err("worker disconnected before completing the job".to_string()))
    }
}

/// Join a batch of handles, preserving submission order.
pub fn join_all<R>(handles: Vec<JobHandle<R>>) -> Vec<Result<R, String>> {
    handles.into_iter().map(|h| h.join()).collect()
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker job panicked".to_string()
    }
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cf-worker-{i}"))
                    .spawn(move || loop {
                        let msg = rx.lock().unwrap().recv();
                        match msg {
                            // Panic isolation: a job that panics must not
                            // kill the worker — spawn() has already
                            // captured the payload for its JobHandle.
                            Ok(Msg::Run(job)) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Ok(Msg::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, handles, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Submit a job and get a [`JobHandle`] to fan its result back in.
    pub fn spawn<F, R>(&self, f: F) -> JobHandle<R>
    where
        F: FnOnce() -> R + Send + 'static,
        R: Send + 'static,
    {
        let (tx, rx) = mpsc::channel();
        self.submit(move || {
            let result = catch_unwind(AssertUnwindSafe(f)).map_err(panic_message);
            let _ = tx.send(result);
        });
        JobHandle { rx }
    }

    /// Map `f` over items in parallel, preserving order. Panics if any
    /// job panicked — use [`ThreadPool::try_map`] to recover instead.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.try_map(items, f)
            .into_iter()
            .map(|r| r.expect("pool job panicked"))
            .collect()
    }

    /// Map `f` over items in parallel, preserving order; each result is
    /// `Err(panic message)` if that item's job panicked.
    pub fn try_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<Result<R, String>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<JobHandle<R>> = items
            .into_iter()
            .map(|item| {
                let f = Arc::clone(&f);
                self.spawn(move || f(item))
            })
            .collect();
        join_all(handles)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<i32>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.submit(|| {});
        drop(pool);
    }

    #[test]
    fn spawn_joins_result() {
        let pool = ThreadPool::new(2);
        let h = pool.spawn(|| 6 * 7);
        assert_eq!(h.join(), Ok(42));
    }

    #[test]
    fn panicking_job_reports_error_and_pool_survives() {
        let pool = ThreadPool::new(1);
        let bad = pool.spawn(|| -> usize { panic!("boom {}", 1 + 1) });
        let err = bad.join().unwrap_err();
        assert!(err.contains("boom"), "got: {err}");
        // The single worker must still be alive to run the next job.
        let good = pool.spawn(|| 7usize);
        assert_eq!(good.join(), Ok(7));
    }

    #[test]
    fn try_map_isolates_panics_per_item() {
        let pool = ThreadPool::new(3);
        let out = pool.try_map((0..10u32).collect(), |x| {
            if x % 4 == 0 {
                panic!("bad item");
            }
            x * 10
        });
        for (i, r) in out.iter().enumerate() {
            if i % 4 == 0 {
                assert!(r.is_err(), "item {i} should have panicked");
            } else {
                assert_eq!(r.as_ref().unwrap(), &(i as u32 * 10));
            }
        }
    }

    #[test]
    fn join_all_preserves_order() {
        let pool = ThreadPool::new(4);
        let handles: Vec<_> = (0..20usize).map(|i| pool.spawn(move || i)).collect();
        let out = join_all(handles);
        for (i, r) in out.into_iter().enumerate() {
            assert_eq!(r, Ok(i));
        }
    }
}
