//! Micro-benchmark harness (criterion replacement, DESIGN.md §9).
//!
//! Used by every target in `benches/` (each with `harness = false`):
//! warmup, fixed-iteration measurement, percentile reporting. Latency
//! samples are wall-clock; throughput helpers derive items/s.

use std::time::Instant;

use super::stats::Summary;

/// One measured benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }

    pub fn report_line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}  ({} iters)",
            self.name,
            fmt_time(s.mean),
            fmt_time(s.p50),
            fmt_time(s.p90),
            fmt_time(s.max),
            self.iters
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Benchmark runner: `Bencher::new("suite").run("case", iters, || work)`.
pub struct Bencher {
    pub suite: String,
    pub results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            "case", "mean", "p50", "p90", "max"
        );
        Bencher { suite: suite.to_string(), results: Vec::new() }
    }

    /// Run `f` for `iters` measured iterations after `warmup` runs.
    pub fn run_with<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        mut f: F,
    ) -> &BenchResult {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        let res = BenchResult {
            name: name.to_string(),
            iters,
            samples,
            summary,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Default warmup = 1, good for second-scale end-to-end cases.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) -> &BenchResult {
        self.run_with(name, 1, iters, f)
    }

    /// Time a single invocation of `f` returning its value + seconds.
    pub fn once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
        let t0 = Instant::now();
        let v = f();
        (v, t0.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep() {
        let mut b = Bencher::new("test");
        let r = b.run_with("spin", 0, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(r.summary.mean >= 0.002);
        assert_eq!(r.samples.len(), 3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
