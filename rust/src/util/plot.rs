//! ASCII line/CDF plots for figure regeneration in terminal reports.

/// Render series of (x, y) points as a fixed-size ascii chart.
/// Multiple series share axes; each gets its own glyph.
pub fn ascii_plot(
    title: &str,
    series: &[(&str, &[(f64, f64)])],
    width: usize,
    height: usize,
) -> String {
    let glyphs = ['*', 'o', '+', 'x', '#', '@'];
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().copied())
        .collect();
    if all.is_empty() {
        return format!("## {title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for &(x, y) in &all {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for &(x, y) in pts.iter() {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = g;
        }
    }
    let mut out = format!("## {title}\n");
    out.push_str(&format!("y: [{ymin:.3}, {ymax:.3}]\n"));
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("x: [{xmin:.3}, {xmax:.3}]   "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", glyphs[si % glyphs.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plots_points() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, (i * i) as f64)).collect();
        let s = ascii_plot("sq", &[("y", &pts)], 40, 10);
        assert!(s.contains('*'));
        assert!(s.contains("sq"));
    }

    #[test]
    fn empty_ok() {
        let s = ascii_plot("e", &[("none", &[])], 10, 5);
        assert!(s.contains("no data"));
    }
}
