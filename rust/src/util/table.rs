//! ASCII table rendering for experiment reports (paper tables/figures
//! are regenerated as aligned text tables + CSV lines).

/// Simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows_str(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// CSV form (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn fmt_x(x: f64) -> String {
    format!("{x:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("t", &["a", "long_header"]);
        t.rows_str(&["1", "2"]);
        t.rows_str(&["100", "20000000"]);
        let r = t.render();
        assert!(r.contains("long_header"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("t", &["x", "y"]);
        t.rows_str(&["1", "2"]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert_eq!(csv.lines().next().unwrap(), "x,y");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a"]);
        t.rows_str(&["1", "2"]);
    }
}
