//! Deterministic PRNG (SplitMix64 core + helpers).
//!
//! Every stochastic component in the system (corpus generation, noise,
//! workload replay) takes an explicit seed so experiments are
//! bit-reproducible; `rand` is not in the offline crate set.

/// SplitMix64: tiny, fast, well-distributed; the canonical seeder.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (for per-video / per-frame seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Standard normal (Box–Muller; one value per call, simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.fork(0);
        let mut b = r.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
