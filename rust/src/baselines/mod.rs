//! The five comparison systems (paper §5), each a configuration of the
//! shared window engine so the comparison isolates *policy*:
//!
//! | Variant    | Transport | Preproc | ViT              | LLM prefill                 |
//! |------------|-----------|---------|------------------|-----------------------------|
//! | Full-Comp  | JPEG      | naive   | full             | full recompute              |
//! | Déjà Vu    | JPEG      | naive   | pixel-diff reuse | full recompute              |
//! | CacheBlend | JPEG      | naive   | full             | reuse + top-k refresh       |
//! | VLCache    | JPEG      | naive   | full             | reuse + fixed-ratio refresh |
//! | CodecFlow  | bitstream | fused   | codec-guided prune | reuse + anchor refresh    |
//!
//! Déjà Vu's learned patch-reuse policy and VLCache's layer-wise
//! profiling are emulated with calibrated thresholds/ratios; both
//! carry their *online* costs measured (pixel diffs; selection) and
//! their *offline* costs documented (DESIGN.md §3) — the deployment
//! distinction in the paper's Table 1.

use crate::config::PipelineConfig;
use crate::pipeline::frontend::FrontendMode;
use crate::pipeline::infer::{KvcMode, RefreshSelect, VariantOpts};
use crate::vision::pruner::PrunerConfig;

/// The five systems under comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    FullComp,
    DejaVu,
    CacheBlend,
    VlCache,
    CodecFlow,
}

impl Variant {
    pub fn all() -> [Variant; 5] {
        [
            Variant::FullComp,
            Variant::DejaVu,
            Variant::CacheBlend,
            Variant::VlCache,
            Variant::CodecFlow,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::FullComp => "Full-Comp",
            Variant::DejaVu => "DejaVu",
            Variant::CacheBlend => "CacheBlend",
            Variant::VlCache => "VLCache",
            Variant::CodecFlow => "CodecFlow",
        }
    }

    /// Transport + decode mode (only CodecFlow operates on the
    /// compressed bitstream end-to-end).
    pub fn frontend_mode(&self) -> FrontendMode {
        match self {
            Variant::CodecFlow => FrontendMode::Bitstream,
            _ => FrontendMode::Jpeg,
        }
    }

    /// Window-engine options for this variant.
    pub fn opts(&self, cfg: &PipelineConfig) -> VariantOpts {
        match self {
            Variant::FullComp => VariantOpts {
                prune: None,
                alpha: 0.0,
                vit_pixel_reuse: None,
                kvc: KvcMode::Recompute,
                fused_preproc: false,
                decode_tokens: cfg.decode_tokens,
            },
            Variant::DejaVu => VariantOpts {
                prune: None,
                alpha: 0.0,
                // pixel-MAD threshold calibrated to match the paper's
                // reported ~70-90% patch similarity on static scenes
                vit_pixel_reuse: Some(2.0),
                kvc: KvcMode::Recompute,
                fused_preproc: false,
                decode_tokens: cfg.decode_tokens,
            },
            Variant::CacheBlend => VariantOpts {
                prune: None,
                alpha: 0.0,
                vit_pixel_reuse: None,
                // paper [78]: ~15% token recompute preserves quality
                kvc: KvcMode::Reuse(RefreshSelect::TopKByChange { frac: 0.15 }),
                fused_preproc: false,
                decode_tokens: cfg.decode_tokens,
            },
            Variant::VlCache => VariantOpts {
                prune: None,
                alpha: 0.0,
                vit_pixel_reuse: None,
                // offline-profiled fixed recompute ratio
                kvc: KvcMode::Reuse(RefreshSelect::FixedRatio { frac: 0.3 }),
                fused_preproc: false,
                decode_tokens: cfg.decode_tokens,
            },
            Variant::CodecFlow => VariantOpts {
                prune: Some(PrunerConfig { tau: cfg.mv_threshold }),
                alpha: cfg.alpha,
                vit_pixel_reuse: None,
                kvc: KvcMode::Reuse(RefreshSelect::Anchors),
                fused_preproc: true,
                decode_tokens: cfg.decode_tokens,
            },
        }
    }

    /// Table 1 row: (optimizes ViT, optimizes LLM, no training, online).
    pub fn table1_row(&self) -> (bool, bool, bool, bool) {
        match self {
            Variant::FullComp => (false, false, true, false),
            Variant::DejaVu => (true, false, false, false),
            Variant::CacheBlend => (false, true, true, false),
            Variant::VlCache => (false, true, false, false),
            Variant::CodecFlow => (true, true, true, true),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codecflow_uses_bitstream_others_jpeg() {
        for v in Variant::all() {
            let want = matches!(v, Variant::CodecFlow);
            assert_eq!(v.frontend_mode() == FrontendMode::Bitstream, want);
        }
    }

    #[test]
    fn opts_match_paper_table1() {
        let cfg = PipelineConfig::default();
        let cf = Variant::CodecFlow.opts(&cfg);
        assert!(cf.prune.is_some());
        assert!(matches!(cf.kvc, KvcMode::Reuse(RefreshSelect::Anchors)));
        let fc = Variant::FullComp.opts(&cfg);
        assert!(fc.prune.is_none());
        assert!(matches!(fc.kvc, KvcMode::Recompute));
        let dv = Variant::DejaVu.opts(&cfg);
        assert!(dv.vit_pixel_reuse.is_some());
        assert!(matches!(dv.kvc, KvcMode::Recompute));
    }

    #[test]
    fn only_codecflow_is_fully_online_trainfree() {
        for v in Variant::all() {
            let (vit, llm, no_train, online) = v.table1_row();
            if v == Variant::CodecFlow {
                assert!(vit && llm && no_train && online);
            } else {
                assert!(!(vit && llm && no_train && online));
            }
        }
    }
}
