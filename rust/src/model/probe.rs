//! Anomaly probe: an analytic linear readout of the VLM's last hidden
//! state (DESIGN.md §4).
//!
//! Why: with deterministic synthetic weights the VLM's *decision
//! function* is real (a fixed function of the full forward pass) but
//! its unembedding is not aligned with "yes"/"no" semantics. The probe
//! restores that alignment without any gradient training: direction =
//! normalized mean difference between anomalous and normal calibration
//! windows (run through the Full-Comp path at startup), threshold =
//! midpoint of the projected class means. Every approximation the
//! paper studies (pruning, KV reuse) perturbs the hidden state and
//! therefore degrades this fixed readout — which is exactly the
//! quantity the accuracy experiments measure.

/// Calibrated probe.
#[derive(Clone, Debug)]
pub struct Probe {
    pub direction: Vec<f32>,
    pub threshold: f32,
    /// Margin between class means in score units (diagnostics).
    pub margin: f32,
    /// Fraction of calibration windows that are positive — used for
    /// per-variant quantile thresholding (score distributions shift
    /// under approximation; rank-based thresholds measure the ranking
    /// degradation the paper's Yes/No head would see).
    pub positive_rate: f64,
}

impl Probe {
    pub fn score(&self, hidden: &[f32]) -> f32 {
        debug_assert_eq!(hidden.len(), self.direction.len());
        hidden.iter().zip(&self.direction).map(|(a, b)| a * b).sum()
    }

    pub fn classify(&self, hidden: &[f32]) -> bool {
        self.score(hidden) > self.threshold
    }
}

/// Accumulates calibration windows, then fits the probe.
#[derive(Clone, Debug, Default)]
pub struct ProbeBuilder {
    pos: Vec<Vec<f32>>,
    neg: Vec<Vec<f32>>,
}

impl ProbeBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, hidden: &[f32], anomalous: bool) {
        if anomalous {
            self.pos.push(hidden.to_vec());
        } else {
            self.neg.push(hidden.to_vec());
        }
    }

    pub fn counts(&self) -> (usize, usize) {
        (self.pos.len(), self.neg.len())
    }

    pub fn ready(&self) -> bool {
        self.pos.len() >= 2 && self.neg.len() >= 2
    }

    fn mean(xs: &[Vec<f32>]) -> Vec<f32> {
        let d = xs[0].len();
        let mut m = vec![0.0f32; d];
        for x in xs {
            for (mi, xi) in m.iter_mut().zip(x) {
                *mi += xi;
            }
        }
        for mi in m.iter_mut() {
            *mi /= xs.len() as f32;
        }
        m
    }

    /// Fit: direction = (mu+ - mu-)/||.||, threshold = midpoint.
    pub fn fit(&self) -> Option<Probe> {
        if self.pos.is_empty() || self.neg.is_empty() {
            return None;
        }
        let mp = Self::mean(&self.pos);
        let mn = Self::mean(&self.neg);
        let mut dir: Vec<f32> = mp.iter().zip(&mn).map(|(a, b)| a - b).collect();
        let norm = dir.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm < 1e-12 {
            return None;
        }
        for d in dir.iter_mut() {
            *d /= norm;
        }
        let sp: f32 = mp.iter().zip(&dir).map(|(a, b)| a * b).sum();
        let sn: f32 = mn.iter().zip(&dir).map(|(a, b)| a * b).sum();
        let positive_rate =
            self.pos.len() as f64 / (self.pos.len() + self.neg.len()) as f64;
        Some(Probe { direction: dir, threshold: (sp + sn) / 2.0, margin: sp - sn, positive_rate })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn separates_gaussian_classes() {
        let mut rng = Rng::new(42);
        let d = 16;
        let mut b = ProbeBuilder::new();
        let gen = |rng: &mut Rng, shift: f32| -> Vec<f32> {
            (0..d)
                .map(|i| rng.normal() as f32 + if i < 4 { shift } else { 0.0 })
                .collect()
        };
        for _ in 0..30 {
            let p = gen(&mut rng, 2.0);
            let n = gen(&mut rng, 0.0);
            b.add(&p, true);
            b.add(&n, false);
        }
        let probe = b.fit().unwrap();
        assert!(probe.margin > 0.0);
        let mut correct = 0;
        for _ in 0..100 {
            if probe.classify(&gen(&mut rng, 2.0)) {
                correct += 1;
            }
            if !probe.classify(&gen(&mut rng, 0.0)) {
                correct += 1;
            }
        }
        assert!(correct > 180, "correct={correct}/200");
    }

    #[test]
    fn fit_requires_both_classes() {
        let mut b = ProbeBuilder::new();
        b.add(&[1.0, 2.0], true);
        assert!(b.fit().is_none());
        assert!(!b.ready());
    }

    #[test]
    fn identical_classes_unfittable() {
        let mut b = ProbeBuilder::new();
        b.add(&[1.0, 1.0], true);
        b.add(&[1.0, 1.0], false);
        assert!(b.fit().is_none());
    }

    #[test]
    fn score_is_linear() {
        let p = Probe { direction: vec![1.0, -1.0], threshold: 0.0, margin: 1.0, positive_rate: 0.5 };
        assert_eq!(p.score(&[3.0, 1.0]), 2.0);
        assert!(p.classify(&[3.0, 1.0]));
        assert!(!p.classify(&[0.0, 5.0]));
    }
}
