//! Prompt handling: the anomaly query template as token ids.
//!
//! The prompt is fixed per deployment (paper §2.1's template query);
//! ids are produced by the AOT pass (configs.prompt_ids) and shipped
//! in the manifest so rust and python agree exactly.

use crate::runtime::manifest::ModelSpec;
use crate::runtime::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct Prompt {
    pub ids: Vec<i32>,
}

impl Prompt {
    pub fn from_spec(spec: &ModelSpec) -> Prompt {
        assert_eq!(spec.prompt_ids.len(), spec.text_len, "prompt length mismatch");
        Prompt { ids: spec.prompt_ids.clone() }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Tensor for the embed_text artifact.
    pub fn tensor(&self) -> Tensor {
        Tensor::i32(&[self.ids.len()], self.ids.clone())
    }
}

/// Decode the yes/no answer from final logits.
pub fn answer_is_yes(logits: &[f32], yes_token: i32, no_token: i32) -> bool {
    logits[yes_token as usize] >= logits[no_token as usize]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::mock::test_spec;

    #[test]
    fn prompt_from_spec() {
        let spec = test_spec("m");
        let p = Prompt::from_spec(&spec);
        assert_eq!(p.len(), spec.text_len);
        assert_eq!(p.tensor().shape(), &[16]);
    }

    #[test]
    fn answer_compare() {
        let mut logits = vec![0.0f32; 8];
        logits[1] = 2.0;
        logits[2] = 1.0;
        assert!(answer_is_yes(&logits, 1, 2));
        logits[2] = 3.0;
        assert!(!answer_is_yes(&logits, 1, 2));
    }
}
