//! Model-level helpers above the runtime: prompt handling, the
//! analytically-calibrated anomaly probe (DESIGN.md §4), and answer
//! decoding.

pub mod probe;
pub mod prompt;

pub use probe::{Probe, ProbeBuilder};
pub use prompt::Prompt;
