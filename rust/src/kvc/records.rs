//! Per-token bookkeeping for sliding-window reuse.

use super::block::KvBlock;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    Visual,
    Text,
}

/// One token of a processed window.
#[derive(Clone, Debug)]
pub struct TokenRecord {
    pub kind: TokenKind,
    /// Absolute frame index in the stream (Visual only; 0 for Text).
    pub frame: usize,
    /// Merge-group index within the frame (Visual only).
    pub group: usize,
    /// Sequence position this token's KV was computed at.
    pub pos: i32,
    /// Whether the source frame is an I-frame (anchor candidate).
    pub is_iframe: bool,
    /// Cached visual embedding (llm_dim) — needed to refresh anchors
    /// through the prefill path without re-running the ViT. Text
    /// tokens don't need it (recomputed from ids each window).
    pub emb: Vec<f32>,
}

/// Everything retained from a processed window: token metadata + the
/// KV cache resident "in GPU memory" (paper §3.4.2 keeps it device-
/// side; our CPU PJRT equivalent keeps it host-side in artifact
/// layout, spliced per window).
#[derive(Clone, Debug)]
pub struct WindowState {
    /// First frame of the window (absolute index).
    pub start_frame: usize,
    /// One past the last frame.
    pub end_frame: usize,
    pub tokens: Vec<TokenRecord>,
    pub k: KvBlock,
    pub v: KvBlock,
    /// Cross-window compression level applied to this state: 0 = raw,
    /// 1 = 2:1 visual-token merge, 2 = 4:1. Bumped by
    /// [`WindowState::merge_partition`].
    pub compression_level: u8,
}

impl WindowState {
    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }

    pub fn bytes(&self) -> usize {
        self.k.bytes()
            + self.v.bytes()
            + self.tokens.iter().map(|t| t.emb.len() * 4).sum::<usize>()
    }

    /// Apply one compression step: collapse every multi-token group of
    /// `partition` (runs of adjacent same-frame visual tokens, see
    /// [`crate::kvc::refresher::compress_partition`]) into a single
    /// averaged token — KV rows and cached embeddings alike; singleton
    /// groups pass through untouched. The surviving record keeps the
    /// first member's metadata (frame, merge group, position, I-frame
    /// flag) so refresh planning and RoPE correction keep working on
    /// the merged block. Returns the number of tokens merged away.
    pub fn merge_partition(&mut self, partition: &[Vec<usize>]) -> usize {
        let before = self.tokens.len();
        self.k = self.k.merge_tokens(partition);
        self.v = self.v.merge_tokens(partition);
        let mut tokens = Vec::with_capacity(partition.len());
        for grp in partition {
            let mut rec = self.tokens[grp[0]].clone();
            if grp.len() > 1 && !rec.emb.is_empty() {
                let mut emb = vec![0.0f32; rec.emb.len()];
                for &i in grp {
                    for (e, x) in emb.iter_mut().zip(&self.tokens[i].emb) {
                        *e += x;
                    }
                }
                let inv = 1.0 / grp.len() as f32;
                for e in emb.iter_mut() {
                    *e *= inv;
                }
                rec.emb = emb;
            }
            tokens.push(rec);
        }
        self.tokens = tokens;
        self.compression_level += 1;
        before - self.tokens.len()
    }

    /// Indices of visual tokens from frames in [lo, hi).
    pub fn visual_in_range(&self, lo: usize, hi: usize) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                t.kind == TokenKind::Visual && t.frame >= lo && t.frame < hi
            })
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(frame: usize, pos: i32, iframe: bool) -> TokenRecord {
        TokenRecord {
            kind: TokenKind::Visual,
            frame,
            group: 0,
            pos,
            is_iframe: iframe,
            emb: vec![0.0; 4],
        }
    }

    #[test]
    fn visual_range_filter() {
        let ws = WindowState {
            start_frame: 0,
            end_frame: 4,
            tokens: vec![
                tok(0, 0, true),
                tok(1, 1, false),
                tok(2, 2, false),
                TokenRecord {
                    kind: TokenKind::Text,
                    frame: 0,
                    group: 0,
                    pos: 3,
                    is_iframe: false,
                    emb: vec![],
                },
            ],
            k: KvBlock::zeros(1, 1, 4, 2),
            v: KvBlock::zeros(1, 1, 4, 2),
            compression_level: 0,
        };
        assert_eq!(ws.visual_in_range(1, 3), vec![1, 2]);
        assert_eq!(ws.visual_in_range(0, 4).len(), 3);
        assert!(ws.bytes() > 0);
    }

    #[test]
    fn merge_partition_halves_and_keeps_metadata() {
        // Two visual tokens on frame 0, one on frame 1, one text token.
        let mut ws = WindowState {
            start_frame: 0,
            end_frame: 2,
            tokens: vec![
                tok(0, 0, true),
                tok(0, 1, true),
                tok(1, 2, false),
                TokenRecord {
                    kind: TokenKind::Text,
                    frame: 0,
                    group: 0,
                    pos: 3,
                    is_iframe: false,
                    emb: vec![],
                },
            ],
            k: KvBlock::from_data(1, 1, 4, 2, (0..8).map(|i| i as f32).collect()),
            v: KvBlock::zeros(1, 1, 4, 2),
            compression_level: 0,
        };
        let bytes_before = ws.bytes();
        let merged = ws.merge_partition(&[vec![0, 1], vec![2], vec![3]]);
        assert_eq!(merged, 1);
        assert_eq!(ws.compression_level, 1);
        assert_eq!(ws.seq_len(), 3);
        // Merged record keeps the first member's metadata.
        assert_eq!(ws.tokens[0].frame, 0);
        assert_eq!(ws.tokens[0].pos, 0);
        assert!(ws.tokens[0].is_iframe);
        // KV row is the mean of the two source rows.
        assert_eq!(ws.k.token_slice(0, 0, 0), &[1.0, 2.0]);
        // Pass-through rows unchanged; footprint shrank.
        assert_eq!(ws.k.token_slice(0, 0, 1), &[4.0, 5.0]);
        assert!(ws.bytes() < bytes_before);
        assert_eq!(ws.visual_in_range(0, 2).len(), 2);
    }
}
