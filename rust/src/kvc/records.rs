//! Per-token bookkeeping for sliding-window reuse.

use super::block::KvBlock;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokenKind {
    Visual,
    Text,
}

/// One token of a processed window.
#[derive(Clone, Debug)]
pub struct TokenRecord {
    pub kind: TokenKind,
    /// Absolute frame index in the stream (Visual only; 0 for Text).
    pub frame: usize,
    /// Merge-group index within the frame (Visual only).
    pub group: usize,
    /// Sequence position this token's KV was computed at.
    pub pos: i32,
    /// Whether the source frame is an I-frame (anchor candidate).
    pub is_iframe: bool,
    /// Cached visual embedding (llm_dim) — needed to refresh anchors
    /// through the prefill path without re-running the ViT. Text
    /// tokens don't need it (recomputed from ids each window).
    pub emb: Vec<f32>,
}

/// Everything retained from a processed window: token metadata + the
/// KV cache resident "in GPU memory" (paper §3.4.2 keeps it device-
/// side; our CPU PJRT equivalent keeps it host-side in artifact
/// layout, spliced per window).
#[derive(Clone, Debug)]
pub struct WindowState {
    /// First frame of the window (absolute index).
    pub start_frame: usize,
    /// One past the last frame.
    pub end_frame: usize,
    pub tokens: Vec<TokenRecord>,
    pub k: KvBlock,
    pub v: KvBlock,
}

impl WindowState {
    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }

    pub fn bytes(&self) -> usize {
        self.k.bytes()
            + self.v.bytes()
            + self.tokens.iter().map(|t| t.emb.len() * 4).sum::<usize>()
    }

    /// Indices of visual tokens from frames in [lo, hi).
    pub fn visual_in_range(&self, lo: usize, hi: usize) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                t.kind == TokenKind::Visual && t.frame >= lo && t.frame < hi
            })
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(frame: usize, pos: i32, iframe: bool) -> TokenRecord {
        TokenRecord {
            kind: TokenKind::Visual,
            frame,
            group: 0,
            pos,
            is_iframe: iframe,
            emb: vec![0.0; 4],
        }
    }

    #[test]
    fn visual_range_filter() {
        let ws = WindowState {
            start_frame: 0,
            end_frame: 4,
            tokens: vec![
                tok(0, 0, true),
                tok(1, 1, false),
                tok(2, 2, false),
                TokenRecord {
                    kind: TokenKind::Text,
                    frame: 0,
                    group: 0,
                    pos: 3,
                    is_iframe: false,
                    emb: vec![],
                },
            ],
            k: KvBlock::zeros(1, 1, 4, 2),
            v: KvBlock::zeros(1, 1, 4, 2),
        };
        assert_eq!(ws.visual_in_range(1, 3), vec![1, 2]);
        assert_eq!(ws.visual_in_range(0, 4).len(), 3);
        assert!(ws.bytes() > 0);
    }
}
