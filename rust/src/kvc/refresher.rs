//! Refresh policy: which overlap tokens to reuse vs recompute.
//!
//! CodecFlow policy (paper §3.4.1): within the overlap region,
//! I-frame-derived tokens are *anchors* — most sensitive to context
//! shift and the reference content of their GOP — and are refreshed
//! through the prefill path from cached embeddings; P-frame tokens are
//! reused after RoPE position correction. The same planner also serves
//! the baselines by letting them override the anchor predicate
//! ([`RefreshPolicy`]), so every variant shares one code path and the
//! comparison isolates the *policy*, not the plumbing.

use super::records::{TokenKind, WindowState};

/// Which overlap tokens must be recomputed (beyond reuse+correction).
#[derive(Clone, Debug)]
pub enum RefreshPolicy {
    /// CodecFlow: refresh I-frame (anchor) tokens.
    Anchors,
    /// Reuse everything (no refresh) — the "naive full reuse" strawman.
    None,
    /// Refresh an explicit set of token indices (baseline emulations:
    /// CacheBlend top-k, VLCache fixed-ratio).
    Explicit(Vec<usize>),
    /// Refresh everything (degenerates to full recompute).
    All,
}

/// The plan for building window t from window t-1.
#[derive(Clone, Debug, Default)]
pub struct ReusePlan {
    /// Indices (into the previous WindowState token list) of tokens to
    /// REUSE, in new-sequence order.
    pub reuse_idx: Vec<usize>,
    /// Position deltas for the reused tokens (new_pos - old_pos).
    pub deltas: Vec<i32>,
    /// New positions of the reused tokens.
    pub new_pos: Vec<i32>,
    /// Indices of overlap tokens to REFRESH (recompute from cached
    /// embeddings), in new-sequence order.
    pub refresh_idx: Vec<usize>,
    /// Frames [lo, hi) whose tokens must be produced fresh (ViT).
    pub fresh_frames: (usize, usize),
}

impl ReusePlan {
    pub fn reused_tokens(&self) -> usize {
        self.reuse_idx.len()
    }

    pub fn refreshed_tokens(&self) -> usize {
        self.refresh_idx.len()
    }
}

/// Plan the transition from `prev` (window over [prev.start, prev.end))
/// to the window [new_start, new_end).
///
/// Sequence-position convention: visual tokens are ordered by (frame,
/// group) and positions are assigned *after* the full new sequence is
/// known (pipeline does that); here we only order and classify.
pub fn plan_window(
    prev: &WindowState,
    new_start: usize,
    new_end: usize,
    policy: &RefreshPolicy,
) -> ReusePlan {
    debug_assert!(new_start >= prev.start_frame);
    let overlap_lo = new_start.max(prev.start_frame);
    let overlap_hi = new_end.min(prev.end_frame);

    // Overlap tokens in (frame, group) order — prev.tokens are already
    // stored in sequence order, which is (frame, group) for visual.
    let overlap: Vec<usize> = prev.visual_in_range(overlap_lo, overlap_hi);

    let refresh_set: Vec<bool> = match policy {
        RefreshPolicy::Anchors => overlap
            .iter()
            .map(|&i| prev.tokens[i].is_iframe)
            .collect(),
        RefreshPolicy::None => vec![false; overlap.len()],
        RefreshPolicy::All => vec![true; overlap.len()],
        RefreshPolicy::Explicit(set) => {
            let lookup: std::collections::HashSet<usize> = set.iter().copied().collect();
            overlap.iter().map(|i| lookup.contains(i)).collect()
        }
    };

    let mut plan = ReusePlan {
        fresh_frames: (prev.end_frame.min(new_end), new_end),
        ..Default::default()
    };
    for (j, &i) in overlap.iter().enumerate() {
        if refresh_set[j] {
            plan.refresh_idx.push(i);
        } else {
            plan.reuse_idx.push(i);
        }
    }
    // Deltas are filled by the pipeline once new positions are known;
    // initialize with zeros of matching length.
    plan.deltas = vec![0; plan.reuse_idx.len()];
    plan.new_pos = vec![0; plan.reuse_idx.len()];
    plan
}

/// Count text tokens in a window state (sanity helper).
pub fn text_tokens(ws: &WindowState) -> usize {
    ws.tokens.iter().filter(|t| t.kind == TokenKind::Text).count()
}

/// Cross-window compression policy — the third arm of the per-block
/// decision. Together with [`plan_window`] the planner is three-way:
/// overlap tokens are *refreshed* (policy above), *kept* as-is, or —
/// when the stream's codec MV energy has stayed below threshold for
/// `after` consecutive windows — *compressed*: the retained
/// [`WindowState`] is merged 2:1 (level 1), and 4:1 after `2*after`
/// calm windows (level 2), up to `max_level`.
#[derive(Clone, Copy, Debug)]
pub struct CompressPolicy {
    /// Calm windows required per compression level (0 disables).
    pub after: usize,
    /// Deepest level: 1 = 2:1, 2 = 4:1.
    pub max_level: u8,
}

impl CompressPolicy {
    /// Target compression level after `calm_windows` consecutive
    /// below-threshold windows.
    pub fn level_for(&self, calm_windows: usize) -> u8 {
        if self.after == 0 {
            return 0;
        }
        (calm_windows / self.after).min(self.max_level as usize) as u8
    }
}

/// Plan one 2:1 compression step for a retained window state: a
/// partition of all token indices, in storage order, where runs of
/// adjacent same-frame visual tokens pair up; text tokens and odd
/// leftovers stay singleton. Feeding the partition to
/// [`WindowState::merge_partition`] halves (rounding up, per frame)
/// the visual token count; applying the next step's partition on the
/// result reaches 4:1.
pub fn compress_partition(state: &WindowState) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = Vec::new();
    let n = state.tokens.len();
    let mut i = 0;
    while i < n {
        let a = &state.tokens[i];
        if a.kind == TokenKind::Visual && i + 1 < n {
            let b = &state.tokens[i + 1];
            if b.kind == TokenKind::Visual && b.frame == a.frame {
                out.push(vec![i, i + 1]);
                i += 2;
                continue;
            }
        }
        out.push(vec![i]);
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::KvBlock;
    use crate::kvc::records::TokenRecord;

    /// 2 tokens per frame, frames [0, 6), I-frames at 0 and 4.
    fn prev_state() -> WindowState {
        let mut tokens = Vec::new();
        let mut pos = 0;
        for frame in 0..6 {
            for group in 0..2 {
                tokens.push(TokenRecord {
                    kind: TokenKind::Visual,
                    frame,
                    group,
                    pos,
                    is_iframe: frame % 4 == 0,
                    emb: vec![frame as f32, group as f32],
                });
                pos += 1;
            }
        }
        for _ in 0..2 {
            tokens.push(TokenRecord {
                kind: TokenKind::Text,
                frame: 0,
                group: 0,
                pos,
                is_iframe: false,
                emb: vec![],
            });
            pos += 1;
        }
        let t = tokens.len();
        WindowState {
            start_frame: 0,
            end_frame: 6,
            tokens,
            k: KvBlock::zeros(1, 1, t, 2),
            v: KvBlock::zeros(1, 1, t, 2),
            compression_level: 0,
        }
    }

    #[test]
    fn anchors_refresh_iframes_only() {
        let prev = prev_state();
        // window slides to [2, 8): overlap frames [2, 6)
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        // overlap = frames 2..6 -> 8 tokens; I-frame 4 -> 2 anchors
        assert_eq!(plan.refresh_idx.len(), 2);
        assert_eq!(plan.reuse_idx.len(), 6);
        for &i in &plan.refresh_idx {
            assert!(prev.tokens[i].is_iframe);
            assert_eq!(prev.tokens[i].frame, 4);
        }
        assert_eq!(plan.fresh_frames, (6, 8));
    }

    #[test]
    fn none_reuses_everything() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::None);
        assert_eq!(plan.refresh_idx.len(), 0);
        assert_eq!(plan.reuse_idx.len(), 8);
    }

    #[test]
    fn all_refreshes_everything() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::All);
        assert_eq!(plan.reuse_idx.len(), 0);
        assert_eq!(plan.refresh_idx.len(), 8);
    }

    #[test]
    fn explicit_set_respected() {
        let prev = prev_state();
        let overlap = prev.visual_in_range(2, 6);
        let chosen = vec![overlap[0], overlap[3]];
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Explicit(chosen.clone()));
        assert_eq!(plan.refresh_idx, chosen);
        assert_eq!(plan.reuse_idx.len(), overlap.len() - 2);
    }

    #[test]
    fn text_tokens_never_in_overlap_plan() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        for &i in plan.reuse_idx.iter().chain(&plan.refresh_idx) {
            assert_eq!(prev.tokens[i].kind, TokenKind::Visual);
        }
        assert_eq!(text_tokens(&prev), 2);
    }

    #[test]
    fn ordering_preserved() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        // reuse indices ascending == (frame, group) order
        for w in plan.reuse_idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    // ---- degenerate inputs -----------------------------------------

    /// Empty window: zero-width new window, and a prev with no tokens.
    #[test]
    fn empty_window_yields_empty_plan() {
        let prev = prev_state();
        let plan = plan_window(&prev, 6, 6, &RefreshPolicy::Anchors);
        assert!(plan.reuse_idx.is_empty() && plan.refresh_idx.is_empty());
        assert_eq!(plan.fresh_frames, (6, 6));

        let empty = WindowState {
            start_frame: 0,
            end_frame: 0,
            tokens: vec![],
            k: KvBlock::zeros(1, 1, 0, 2),
            v: KvBlock::zeros(1, 1, 0, 2),
            compression_level: 0,
        };
        let plan = plan_window(&empty, 0, 4, &RefreshPolicy::Anchors);
        assert!(plan.reuse_idx.is_empty() && plan.refresh_idx.is_empty());
        assert_eq!(plan.fresh_frames, (0, 4));
        assert!(compress_partition(&empty).is_empty());
    }

    /// All-I-frame GOP: every overlap token is an anchor, so the
    /// Anchors policy refreshes everything — nothing is reused.
    #[test]
    fn all_iframe_gop_refreshes_everything() {
        let mut prev = prev_state();
        for t in prev.tokens.iter_mut() {
            if t.kind == TokenKind::Visual {
                t.is_iframe = true;
            }
        }
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        assert_eq!(plan.reuse_idx.len(), 0);
        assert_eq!(plan.refresh_idx.len(), 8);
    }

    /// Zero-MV stream: every visual token is compress-eligible — the
    /// level ramps with consecutive calm windows and one partition
    /// step pairs every same-frame visual run (text stays singleton).
    #[test]
    fn zero_mv_stream_compresses_everything() {
        let pol = CompressPolicy { after: 2, max_level: 2 };
        assert_eq!(pol.level_for(0), 0);
        assert_eq!(pol.level_for(1), 0);
        assert_eq!(pol.level_for(2), 1);
        assert_eq!(pol.level_for(3), 1);
        assert_eq!(pol.level_for(4), 2);
        assert_eq!(pol.level_for(100), 2, "level is capped at max_level");

        let mut prev = prev_state(); // 2 visual tokens per frame + 2 text
        let part = compress_partition(&prev);
        // 6 frames * 1 pair + 2 text singletons
        assert_eq!(part.len(), 8);
        assert_eq!(part.iter().filter(|g| g.len() == 2).count(), 6);
        let merged = prev.merge_partition(&part);
        assert_eq!(merged, 6);
        assert_eq!(prev.compression_level, 1);
        assert_eq!(text_tokens(&prev), 2);
        // Level 2: one visual token per frame left, nothing pairs
        // within a frame — 2:1 per level bottoms out at one per frame.
        let part2 = compress_partition(&prev);
        assert!(part2.iter().all(|g| g.len() == 1));
    }

    /// Disabled policy (after = 0) never compresses.
    #[test]
    fn disabled_policy_never_compresses() {
        let pol = CompressPolicy { after: 0, max_level: 2 };
        for calm in 0..50 {
            assert_eq!(pol.level_for(calm), 0);
        }
    }

    /// One-block budget: a single visual token per frame — plan_window
    /// still classifies it, and compression keeps it (never merges a
    /// block below one token).
    #[test]
    fn one_block_budget_is_kept_not_merged() {
        let one = WindowState {
            start_frame: 0,
            end_frame: 1,
            tokens: vec![TokenRecord {
                kind: TokenKind::Visual,
                frame: 0,
                group: 0,
                pos: 0,
                is_iframe: false,
                emb: vec![1.0, 2.0],
            }],
            k: KvBlock::zeros(1, 1, 1, 2),
            v: KvBlock::zeros(1, 1, 1, 2),
            compression_level: 0,
        };
        let plan = plan_window(&one, 0, 2, &RefreshPolicy::Anchors);
        assert_eq!(plan.reuse_idx, vec![0]);
        assert!(plan.refresh_idx.is_empty());
        let part = compress_partition(&one);
        assert_eq!(part, vec![vec![0]]);
        let mut state = one.clone();
        assert_eq!(state.merge_partition(&part), 0);
        assert_eq!(state.seq_len(), 1);
    }
}
