//! Refresh policy: which overlap tokens to reuse vs recompute.
//!
//! CodecFlow policy (paper §3.4.1): within the overlap region,
//! I-frame-derived tokens are *anchors* — most sensitive to context
//! shift and the reference content of their GOP — and are refreshed
//! through the prefill path from cached embeddings; P-frame tokens are
//! reused after RoPE position correction. The same planner also serves
//! the baselines by letting them override the anchor predicate
//! ([`RefreshPolicy`]), so every variant shares one code path and the
//! comparison isolates the *policy*, not the plumbing.

use super::records::{TokenKind, WindowState};

/// Which overlap tokens must be recomputed (beyond reuse+correction).
#[derive(Clone, Debug)]
pub enum RefreshPolicy {
    /// CodecFlow: refresh I-frame (anchor) tokens.
    Anchors,
    /// Reuse everything (no refresh) — the "naive full reuse" strawman.
    None,
    /// Refresh an explicit set of token indices (baseline emulations:
    /// CacheBlend top-k, VLCache fixed-ratio).
    Explicit(Vec<usize>),
    /// Refresh everything (degenerates to full recompute).
    All,
}

/// The plan for building window t from window t-1.
#[derive(Clone, Debug, Default)]
pub struct ReusePlan {
    /// Indices (into the previous WindowState token list) of tokens to
    /// REUSE, in new-sequence order.
    pub reuse_idx: Vec<usize>,
    /// Position deltas for the reused tokens (new_pos - old_pos).
    pub deltas: Vec<i32>,
    /// New positions of the reused tokens.
    pub new_pos: Vec<i32>,
    /// Indices of overlap tokens to REFRESH (recompute from cached
    /// embeddings), in new-sequence order.
    pub refresh_idx: Vec<usize>,
    /// Frames [lo, hi) whose tokens must be produced fresh (ViT).
    pub fresh_frames: (usize, usize),
}

impl ReusePlan {
    pub fn reused_tokens(&self) -> usize {
        self.reuse_idx.len()
    }

    pub fn refreshed_tokens(&self) -> usize {
        self.refresh_idx.len()
    }
}

/// Plan the transition from `prev` (window over [prev.start, prev.end))
/// to the window [new_start, new_end).
///
/// Sequence-position convention: visual tokens are ordered by (frame,
/// group) and positions are assigned *after* the full new sequence is
/// known (pipeline does that); here we only order and classify.
pub fn plan_window(
    prev: &WindowState,
    new_start: usize,
    new_end: usize,
    policy: &RefreshPolicy,
) -> ReusePlan {
    debug_assert!(new_start >= prev.start_frame);
    let overlap_lo = new_start.max(prev.start_frame);
    let overlap_hi = new_end.min(prev.end_frame);

    // Overlap tokens in (frame, group) order — prev.tokens are already
    // stored in sequence order, which is (frame, group) for visual.
    let overlap: Vec<usize> = prev.visual_in_range(overlap_lo, overlap_hi);

    let refresh_set: Vec<bool> = match policy {
        RefreshPolicy::Anchors => overlap
            .iter()
            .map(|&i| prev.tokens[i].is_iframe)
            .collect(),
        RefreshPolicy::None => vec![false; overlap.len()],
        RefreshPolicy::All => vec![true; overlap.len()],
        RefreshPolicy::Explicit(set) => {
            let lookup: std::collections::HashSet<usize> = set.iter().copied().collect();
            overlap.iter().map(|i| lookup.contains(i)).collect()
        }
    };

    let mut plan = ReusePlan {
        fresh_frames: (prev.end_frame.min(new_end), new_end),
        ..Default::default()
    };
    for (j, &i) in overlap.iter().enumerate() {
        if refresh_set[j] {
            plan.refresh_idx.push(i);
        } else {
            plan.reuse_idx.push(i);
        }
    }
    // Deltas are filled by the pipeline once new positions are known;
    // initialize with zeros of matching length.
    plan.deltas = vec![0; plan.reuse_idx.len()];
    plan.new_pos = vec![0; plan.reuse_idx.len()];
    plan
}

/// Count text tokens in a window state (sanity helper).
pub fn text_tokens(ws: &WindowState) -> usize {
    ws.tokens.iter().filter(|t| t.kind == TokenKind::Text).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvc::block::KvBlock;
    use crate::kvc::records::TokenRecord;

    /// 2 tokens per frame, frames [0, 6), I-frames at 0 and 4.
    fn prev_state() -> WindowState {
        let mut tokens = Vec::new();
        let mut pos = 0;
        for frame in 0..6 {
            for group in 0..2 {
                tokens.push(TokenRecord {
                    kind: TokenKind::Visual,
                    frame,
                    group,
                    pos,
                    is_iframe: frame % 4 == 0,
                    emb: vec![frame as f32, group as f32],
                });
                pos += 1;
            }
        }
        for _ in 0..2 {
            tokens.push(TokenRecord {
                kind: TokenKind::Text,
                frame: 0,
                group: 0,
                pos,
                is_iframe: false,
                emb: vec![],
            });
            pos += 1;
        }
        let t = tokens.len();
        WindowState {
            start_frame: 0,
            end_frame: 6,
            tokens,
            k: KvBlock::zeros(1, 1, t, 2),
            v: KvBlock::zeros(1, 1, t, 2),
        }
    }

    #[test]
    fn anchors_refresh_iframes_only() {
        let prev = prev_state();
        // window slides to [2, 8): overlap frames [2, 6)
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        // overlap = frames 2..6 -> 8 tokens; I-frame 4 -> 2 anchors
        assert_eq!(plan.refresh_idx.len(), 2);
        assert_eq!(plan.reuse_idx.len(), 6);
        for &i in &plan.refresh_idx {
            assert!(prev.tokens[i].is_iframe);
            assert_eq!(prev.tokens[i].frame, 4);
        }
        assert_eq!(plan.fresh_frames, (6, 8));
    }

    #[test]
    fn none_reuses_everything() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::None);
        assert_eq!(plan.refresh_idx.len(), 0);
        assert_eq!(plan.reuse_idx.len(), 8);
    }

    #[test]
    fn all_refreshes_everything() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::All);
        assert_eq!(plan.reuse_idx.len(), 0);
        assert_eq!(plan.refresh_idx.len(), 8);
    }

    #[test]
    fn explicit_set_respected() {
        let prev = prev_state();
        let overlap = prev.visual_in_range(2, 6);
        let chosen = vec![overlap[0], overlap[3]];
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Explicit(chosen.clone()));
        assert_eq!(plan.refresh_idx, chosen);
        assert_eq!(plan.reuse_idx.len(), overlap.len() - 2);
    }

    #[test]
    fn text_tokens_never_in_overlap_plan() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        for &i in plan.reuse_idx.iter().chain(&plan.refresh_idx) {
            assert_eq!(prev.tokens[i].kind, TokenKind::Visual);
        }
        assert_eq!(text_tokens(&prev), 2);
    }

    #[test]
    fn ordering_preserved() {
        let prev = prev_state();
        let plan = plan_window(&prev, 2, 8, &RefreshPolicy::Anchors);
        // reuse indices ascending == (frame, group) order
        for w in plan.reuse_idx.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
