//! Cross-session KV memory pool: accounting + LRU eviction.
//!
//! The paper keeps each stream's previous-window KV resident in GPU
//! memory (§3.4.2). With many concurrent streams that residency is a
//! budgeted resource; the coordinator uses this pool to decide which
//! stream loses its cache (and must fall back to full prefill) under
//! memory pressure — the multi-stream behaviour Fig 11's throughput
//! claim depends on.

use std::collections::HashMap;

/// Tracks bytes held per session, with an LRU clock.
#[derive(Debug, Default)]
pub struct KvPool {
    pub budget_bytes: usize,
    held: HashMap<u64, (usize, u64)>, // session -> (bytes, last_touch)
    clock: u64,
    pub evictions: u64,
}

impl KvPool {
    pub fn new(budget_bytes: usize) -> Self {
        KvPool { budget_bytes, ..Default::default() }
    }

    pub fn used_bytes(&self) -> usize {
        self.held.values().map(|(b, _)| b).sum()
    }

    pub fn sessions(&self) -> usize {
        self.held.len()
    }

    /// Record that `session` now holds `bytes`; returns the sessions
    /// evicted (their caches must be dropped by the caller).
    pub fn hold(&mut self, session: u64, bytes: usize) -> Vec<u64> {
        self.hold_protected(session, bytes, |_| false)
    }

    /// [`KvPool::hold`] with a victim filter: sessions for which
    /// `protected` returns true are never chosen for eviction. The
    /// pipelined shard loop protects streams with an in-flight window
    /// — their cache is about to be rewritten by a finish that has
    /// already launched, so evicting them would be silently undone
    /// (the session restores its state, the pool thinks it is gone).
    /// With every candidate protected the pool may transiently exceed
    /// its budget by the in-flight working set; pressure is re-applied
    /// at the next settlement.
    pub fn hold_protected(
        &mut self,
        session: u64,
        bytes: usize,
        protected: impl Fn(u64) -> bool,
    ) -> Vec<u64> {
        self.clock += 1;
        self.held.insert(session, (bytes, self.clock));
        let mut evicted = Vec::new();
        while self.used_bytes() > self.budget_bytes && self.held.len() > 1 {
            // Evict least-recently-touched other, unprotected session.
            let victim = self
                .held
                .iter()
                .filter(|(&s, _)| s != session && !protected(s))
                .min_by_key(|(_, (_, touch))| *touch)
                .map(|(&s, _)| s);
            match victim {
                Some(s) => {
                    self.held.remove(&s);
                    self.evictions += 1;
                    evicted.push(s);
                }
                None => break,
            }
        }
        evicted
    }

    pub fn touch(&mut self, session: u64) {
        self.clock += 1;
        if let Some(e) = self.held.get_mut(&session) {
            e.1 = self.clock;
        }
    }

    /// Shrink `session`'s held bytes in place — the second release
    /// path: cross-window KV compression returning budget to the pool
    /// without giving up residency. Unlike [`KvPool::hold`] this does
    /// not bump the LRU clock (compression is not a use) and can never
    /// trigger eviction (usage only decreases). No-op when the session
    /// holds nothing (e.g. already quarantine-released — the paths
    /// compose) or when `new_bytes` is not smaller. Returns the bytes
    /// freed.
    pub fn shrink(&mut self, session: u64, new_bytes: usize) -> usize {
        match self.held.get_mut(&session) {
            Some(e) if new_bytes < e.0 => {
                let freed = e.0 - new_bytes;
                e.0 = new_bytes;
                freed
            }
            _ => 0,
        }
    }

    pub fn release(&mut self, session: u64) {
        self.held.remove(&session);
    }

    pub fn holds(&self, session: u64) -> bool {
        self.held.contains_key(&session)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_lru_order() {
        let mut p = KvPool::new(100);
        assert!(p.hold(1, 40).is_empty());
        assert!(p.hold(2, 40).is_empty());
        p.touch(1); // 2 is now LRU
        let evicted = p.hold(3, 40);
        assert_eq!(evicted, vec![2]);
        assert!(p.holds(1) && p.holds(3) && !p.holds(2));
        assert_eq!(p.evictions, 1);
    }

    #[test]
    fn never_evicts_the_holder() {
        let mut p = KvPool::new(10);
        let evicted = p.hold(1, 50); // over budget but alone
        assert!(evicted.is_empty());
        assert!(p.holds(1));
    }

    #[test]
    fn protected_sessions_are_never_victims() {
        let mut p = KvPool::new(100);
        assert!(p.hold(1, 40).is_empty());
        assert!(p.hold(2, 40).is_empty());
        // Session 1 is LRU but protected: 2 is evicted instead.
        let evicted = p.hold_protected(3, 40, |s| s == 1);
        assert_eq!(evicted, vec![2]);
        assert!(p.holds(1) && p.holds(3));
        // Everything protected: over budget, nothing evicted.
        let evicted = p.hold_protected(4, 40, |_| true);
        assert!(evicted.is_empty());
        assert!(p.used_bytes() > p.budget_bytes, "transiently over budget");
        assert_eq!(p.evictions, 1);
    }

    #[test]
    fn release_frees() {
        let mut p = KvPool::new(100);
        p.hold(1, 60);
        p.release(1);
        assert_eq!(p.used_bytes(), 0);
        assert!(p.hold(2, 80).is_empty());
    }

    #[test]
    fn shrink_frees_without_touching_lru_order() {
        let mut p = KvPool::new(100);
        p.hold(1, 40);
        p.hold(2, 40);
        // Compress session 1 (the LRU): frees 20 bytes, but does NOT
        // count as a touch — 1 must still be the next victim.
        assert_eq!(p.shrink(1, 20), 20);
        assert_eq!(p.used_bytes(), 60);
        let evicted = p.hold(3, 50);
        assert_eq!(evicted, vec![1]);
        // Growing or absent sessions are no-ops.
        assert_eq!(p.shrink(2, 90), 0);
        assert_eq!(p.shrink(99, 1), 0);
        assert_eq!(p.used_bytes(), 90);
    }

    /// Satellite barrage: random admit/settle/quarantine/compress/
    /// release sequences against a mirror ledger. Invariants after
    /// every op: pool usage equals the ledger sum (allocated + free ==
    /// capacity, nothing leaks), session counts agree, and compression
    /// (shrink) composes with quarantine (release) — shrinking a
    /// released session must not resurrect it.
    #[test]
    fn prop_accounting_under_random_op_sequences() {
        use crate::util::quick;
        use std::collections::HashMap;
        quick::check(0x4B50, 60, |g| {
            let budget = 50 + g.usize_in(0, 200);
            let mut p = KvPool::new(budget);
            let mut ledger: HashMap<u64, usize> = HashMap::new();
            let n_sessions = g.usize_in(2, 8) as u64;
            for _ in 0..g.usize_in(10, 60) {
                let s = g.usize_in(0, n_sessions as usize - 1) as u64;
                match g.usize_in(0, 4) {
                    // admit / settle: hold fresh bytes
                    0 | 1 => {
                        let bytes = g.usize_in(1, 80);
                        let evicted = p.hold(s, bytes);
                        ledger.insert(s, bytes);
                        for v in evicted {
                            assert_ne!(v, s, "holder must never be evicted");
                            assert!(
                                ledger.remove(&v).is_some(),
                                "evicted a session the ledger did not hold"
                            );
                        }
                    }
                    // compress: shrink to a smaller footprint
                    2 => {
                        let new_bytes = g.usize_in(0, 40);
                        let freed = p.shrink(s, new_bytes);
                        match ledger.get_mut(&s) {
                            Some(b) if new_bytes < *b => {
                                assert_eq!(freed, *b - new_bytes);
                                *b = new_bytes;
                            }
                            _ => assert_eq!(freed, 0),
                        }
                    }
                    // quarantine / stream end: release
                    3 => {
                        p.release(s);
                        ledger.remove(&s);
                        // compress-after-quarantine must be inert
                        assert_eq!(p.shrink(s, 0), 0);
                        assert!(!p.holds(s));
                    }
                    // plain LRU touch
                    _ => p.touch(s),
                }
                // No leaks, no phantom frees: pool == ledger.
                assert_eq!(p.used_bytes(), ledger.values().sum::<usize>());
                assert_eq!(p.sessions(), ledger.len());
                for (&s, &b) in &ledger {
                    assert!(p.holds(s), "session {s} leaked ({b} bytes)");
                }
            }
        });
    }
}
