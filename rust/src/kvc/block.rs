//! KV tensor block in the artifact layout `[L, H, T, hd]`.

/// A block of K or V states for `t` tokens.
#[derive(Clone, Debug, PartialEq)]
pub struct KvBlock {
    pub layers: usize,
    pub heads: usize,
    pub t: usize,
    pub head_dim: usize,
    /// Row-major `[layers, heads, t, head_dim]`.
    pub data: Vec<f32>,
}

impl KvBlock {
    pub fn zeros(layers: usize, heads: usize, t: usize, head_dim: usize) -> Self {
        KvBlock { layers, heads, t, head_dim, data: vec![0.0; layers * heads * t * head_dim] }
    }

    pub fn from_data(
        layers: usize,
        heads: usize,
        t: usize,
        head_dim: usize,
        data: Vec<f32>,
    ) -> Self {
        assert_eq!(data.len(), layers * heads * t * head_dim);
        KvBlock { layers, heads, t, head_dim, data }
    }

    #[inline]
    pub fn offset(&self, l: usize, h: usize, tok: usize) -> usize {
        ((l * self.heads + h) * self.t + tok) * self.head_dim
    }

    pub fn token_slice(&self, l: usize, h: usize, tok: usize) -> &[f32] {
        let o = self.offset(l, h, tok);
        &self.data[o..o + self.head_dim]
    }

    /// Gather a subset of tokens (new block with t = idx.len()).
    pub fn gather(&self, idx: &[usize]) -> KvBlock {
        let mut out = KvBlock::zeros(self.layers, self.heads, idx.len(), self.head_dim);
        for l in 0..self.layers {
            for h in 0..self.heads {
                for (j, &i) in idx.iter().enumerate() {
                    debug_assert!(i < self.t);
                    let src = self.offset(l, h, i);
                    let dst = out.offset(l, h, j);
                    out.data[dst..dst + self.head_dim]
                        .copy_from_slice(&self.data[src..src + self.head_dim]);
                }
            }
        }
        out
    }

    /// Concatenate along the token axis.
    pub fn concat(&self, other: &KvBlock) -> KvBlock {
        assert_eq!(
            (self.layers, self.heads, self.head_dim),
            (other.layers, other.heads, other.head_dim)
        );
        let t = self.t + other.t;
        let mut out = KvBlock::zeros(self.layers, self.heads, t, self.head_dim);
        for l in 0..self.layers {
            for h in 0..self.heads {
                let dst0 = out.offset(l, h, 0);
                let src0 = self.offset(l, h, 0);
                let n1 = self.t * self.head_dim;
                out.data[dst0..dst0 + n1].copy_from_slice(&self.data[src0..src0 + n1]);
                let dst1 = out.offset(l, h, self.t);
                let osrc = other.offset(l, h, 0);
                let n2 = other.t * self.head_dim;
                out.data[dst1..dst1 + n2].copy_from_slice(&other.data[osrc..osrc + n2]);
            }
        }
        out
    }

    /// Zero-pad the token axis up to `t_bucket`; returns the padded
    /// block and the validity mask.
    pub fn pad_to(&self, t_bucket: usize) -> (KvBlock, Vec<f32>) {
        assert!(t_bucket >= self.t, "bucket {t_bucket} < t {}", self.t);
        let mut out = KvBlock::zeros(self.layers, self.heads, t_bucket, self.head_dim);
        for l in 0..self.layers {
            for h in 0..self.heads {
                let src = self.offset(l, h, 0);
                let dst = out.offset(l, h, 0);
                let n = self.t * self.head_dim;
                out.data[dst..dst + n].copy_from_slice(&self.data[src..src + n]);
            }
        }
        let mut mask = vec![0.0f32; t_bucket];
        mask[..self.t].fill(1.0);
        (out, mask)
    }

    /// Keep only the first `t` tokens (drop bucket padding).
    pub fn truncate(&self, t: usize) -> KvBlock {
        assert!(t <= self.t);
        self.gather(&(0..t).collect::<Vec<_>>())
    }

    /// Collapse each group of token indices into one averaged token
    /// (new block with `t = groups.len()`). The deterministic merge
    /// primitive behind cross-window KV compression: a 2:1 partition
    /// halves the token axis, applying it twice yields 4:1. Singleton
    /// groups copy through unchanged, so `merge_tokens` with an
    /// all-singleton partition equals [`KvBlock::gather`].
    pub fn merge_tokens(&self, groups: &[Vec<usize>]) -> KvBlock {
        let mut out = KvBlock::zeros(self.layers, self.heads, groups.len(), self.head_dim);
        for l in 0..self.layers {
            for h in 0..self.heads {
                for (j, grp) in groups.iter().enumerate() {
                    assert!(!grp.is_empty(), "empty merge group");
                    let dst = out.offset(l, h, j);
                    for &i in grp {
                        debug_assert!(i < self.t);
                        let src = self.offset(l, h, i);
                        for d in 0..self.head_dim {
                            out.data[dst + d] += self.data[src + d];
                        }
                    }
                    let inv = 1.0 / grp.len() as f32;
                    for d in 0..self.head_dim {
                        out.data[dst + d] *= inv;
                    }
                }
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick;

    fn sample(l: usize, h: usize, t: usize, hd: usize) -> KvBlock {
        let n = l * h * t * hd;
        KvBlock::from_data(l, h, t, hd, (0..n).map(|i| i as f32).collect())
    }

    #[test]
    fn gather_identity() {
        let b = sample(2, 3, 5, 4);
        let idx: Vec<usize> = (0..5).collect();
        assert_eq!(b.gather(&idx), b);
    }

    #[test]
    fn gather_selects_tokens() {
        let b = sample(2, 2, 4, 2);
        let g = b.gather(&[3, 1]);
        assert_eq!(g.t, 2);
        assert_eq!(g.token_slice(0, 0, 0), b.token_slice(0, 0, 3));
        assert_eq!(g.token_slice(1, 1, 1), b.token_slice(1, 1, 1));
    }

    #[test]
    fn concat_then_split_roundtrip() {
        let a = sample(2, 2, 3, 4);
        let b = sample(2, 2, 2, 4);
        let c = a.concat(&b);
        assert_eq!(c.t, 5);
        assert_eq!(c.truncate(3), a);
        assert_eq!(c.gather(&[3, 4]), b);
    }

    #[test]
    fn pad_mask() {
        let a = sample(1, 1, 3, 2);
        let (p, mask) = a.pad_to(5);
        assert_eq!(p.t, 5);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0, 0.0]);
        assert_eq!(p.token_slice(0, 0, 4), &[0.0, 0.0]);
        assert_eq!(p.truncate(3), a);
    }

    #[test]
    fn merge_singletons_is_gather() {
        let b = sample(2, 2, 4, 3);
        let groups: Vec<Vec<usize>> = vec![vec![2], vec![0], vec![3]];
        let idx: Vec<usize> = groups.iter().map(|g| g[0]).collect();
        assert_eq!(b.merge_tokens(&groups), b.gather(&idx));
    }

    #[test]
    fn merge_pairs_averages() {
        let b = sample(1, 2, 4, 2);
        let m = b.merge_tokens(&[vec![0, 1], vec![2, 3]]);
        assert_eq!(m.t, 2);
        for h in 0..2 {
            for d in 0..2 {
                let want = (b.token_slice(0, h, 0)[d] + b.token_slice(0, h, 1)[d]) / 2.0;
                assert_eq!(m.token_slice(0, h, 0)[d], want);
                let want = (b.token_slice(0, h, 2)[d] + b.token_slice(0, h, 3)[d]) / 2.0;
                assert_eq!(m.token_slice(0, h, 1)[d], want);
            }
        }
    }

    #[test]
    fn merge_twice_is_four_to_one() {
        let b = sample(1, 1, 8, 2);
        let l1 = b.merge_tokens(&[vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]);
        let l2 = l1.merge_tokens(&[vec![0, 1], vec![2, 3]]);
        assert_eq!(l2.t, 2);
        let direct = b.merge_tokens(&[vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        for (a, c) in l2.data.iter().zip(&direct.data) {
            assert!((a - c).abs() < 1e-5, "4:1 via two 2:1 steps diverged");
        }
    }

    #[test]
    fn prop_gather_concat_consistency() {
        quick::check(0x4B56, 40, |g| {
            let (l, h, hd) = (g.usize_in(1, 3), g.usize_in(1, 3), 2 * g.usize_in(1, 4));
            let ta = g.usize_in(1, 6);
            let tb = g.usize_in(1, 6);
            let a = KvBlock::from_data(
                l, h, ta, hd,
                g.vec_f32(l * h * ta * hd, -2.0, 2.0),
            );
            let b = KvBlock::from_data(
                l, h, tb, hd,
                g.vec_f32(l * h * tb * hd, -2.0, 2.0),
            );
            let c = a.concat(&b);
            // every token of the concat maps back to its source
            for tok in 0..ta {
                assert_eq!(c.token_slice(l - 1, h - 1, tok), a.token_slice(l - 1, h - 1, tok));
            }
            for tok in 0..tb {
                assert_eq!(
                    c.token_slice(0, 0, ta + tok),
                    b.token_slice(0, 0, tok)
                );
            }
            // gather of a random permutation preserves slices
            let mut idx: Vec<usize> = (0..c.t).collect();
            g.rng.shuffle(&mut idx);
            let gathered = c.gather(&idx);
            for (j, &i) in idx.iter().enumerate() {
                assert_eq!(gathered.token_slice(0, 0, j), c.token_slice(0, 0, i));
            }
        });
    }
}
