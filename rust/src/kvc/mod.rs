//! Selective KV-cache refresh across sliding windows (paper §3.4).
//!
//! * [`block`] — KV tensor block in artifact layout `[L, H, T, hd]`
//!   with token-axis gather / concat / pad;
//! * [`rope`] — eq. 5 position correction: rotate reused keys by
//!   `new_pos - old_pos` host-side (values reused directly);
//! * [`records`] — per-token bookkeeping (source frame, group, kind,
//!   position, cached embedding, I-frame flag);
//! * [`refresher`] — the policy: overlap tokens from I-frames are
//!   anchors (recomputed through the prefill path from their cached
//!   embeddings, without re-running the ViT), P-frame tokens are
//!   reused with position correction; extended to a three-way
//!   compress-vs-refresh-vs-keep plan — blocks whose codec MV energy
//!   stays calm across consecutive windows are merged 2:1 then 4:1
//!   ([`refresher::CompressPolicy`], [`refresher::compress_partition`]);
//! * [`pool`] — cross-session KV memory accounting + LRU eviction,
//!   with compression ([`pool::KvPool::shrink`]) as a second release
//!   path composing with quarantine release.
//!
//! Known approximation (shared with CacheBlend-style systems): tokens
//! recomputed in the "new" block attend to *all* reused entries, even
//! ones whose sequence position is later — the position-corrected keys
//! keep relative geometry right, but strict causality across the
//! reused/new boundary is relaxed. The accuracy experiments (Fig 12,
//! 15) measure exactly the cost of this class of approximation.

pub mod block;
pub mod pool;
pub mod records;
pub mod refresher;
pub mod rope;

pub use block::KvBlock;
pub use records::{TokenKind, TokenRecord, WindowState};
pub use refresher::{compress_partition, plan_window, CompressPolicy, ReusePlan, RefreshPolicy};
