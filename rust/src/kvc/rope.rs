//! RoPE position correction for reused keys (paper eq. 5).
//!
//! `K̂_t(j) = R(p_new(j) - p_old(j)) K_{t-1}(j)` — rotations compose,
//! so correcting by the position *delta* re-bases a cached key to its
//! new sequence position without recomputing it. Values carry no
//! positional encoding in RoPE attention and are reused as-is.
//!
//! Host-side implementation (no PJRT round trip): the correction is
//! O(L·H·T·hd) multiply-adds — `runtime::flops::rope_correct` shows it
//! is ~4 orders of magnitude below a prefill, and Fig 19 measures it.
//!
//! Convention must match python/compile/kernels/ref.py (half-split):
//! pairs are (x_i, x_{i+hd/2}), angle_i = delta * base^(-i / (hd/2)).

use std::collections::HashMap;

use super::block::KvBlock;

/// cos/sin table for one delta value: [(cos_i, sin_i); hd/2].
fn angle_table(delta: i32, half: usize, base: f64) -> Vec<(f32, f32)> {
    (0..half)
        .map(|i| {
            let inv_freq = base.powf(-(i as f64) / half as f64);
            let ang = delta as f64 * inv_freq;
            (ang.cos() as f32, ang.sin() as f32)
        })
        .collect()
}

/// Rotate each token's keys by its position delta, in place.
/// `deltas.len() == k.t`. Tokens with delta 0 are untouched.
pub fn correct_keys(k: &mut KvBlock, deltas: &[i32], base: f64) {
    assert_eq!(deltas.len(), k.t);
    let half = k.head_dim / 2;
    // Sliding windows shift most tokens by the same delta: cache the
    // per-delta tables (typically 1-2 entries).
    let mut tables: HashMap<i32, Vec<(f32, f32)>> = HashMap::new();
    for (tok, &d) in deltas.iter().enumerate() {
        if d == 0 {
            continue;
        }
        let table =
            tables.entry(d).or_insert_with(|| angle_table(d, half, base)).clone();
        for l in 0..k.layers {
            for h in 0..k.heads {
                let o = k.offset(l, h, tok);
                let (lo, hi) = k.data[o..o + k.head_dim].split_at_mut(half);
                for i in 0..half {
                    let (c, s) = table[i];
                    let x1 = lo[i];
                    let x2 = hi[i];
                    lo[i] = x1 * c - x2 * s;
                    hi[i] = x2 * c + x1 * s;
                }
            }
        }
    }
}

/// Out-of-place convenience.
pub fn corrected(k: &KvBlock, deltas: &[i32], base: f64) -> KvBlock {
    let mut out = k.clone();
    correct_keys(&mut out, deltas, base);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prng::Rng, quick};

    fn rand_block(rng: &mut Rng, l: usize, h: usize, t: usize, hd: usize) -> KvBlock {
        let n = l * h * t * hd;
        KvBlock::from_data(l, h, t, hd, (0..n).map(|_| rng.normal() as f32).collect())
    }

    #[test]
    fn zero_delta_is_identity() {
        let mut rng = Rng::new(1);
        let k = rand_block(&mut rng, 2, 2, 4, 8);
        let out = corrected(&k, &[0; 4], 1e4);
        assert_eq!(k, out);
    }

    #[test]
    fn rotation_composes() {
        let mut rng = Rng::new(2);
        let k = rand_block(&mut rng, 1, 2, 3, 8);
        let a = corrected(&corrected(&k, &[2; 3], 1e4), &[3; 3], 1e4);
        let b = corrected(&k, &[5; 3], 1e4);
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn rotation_invertible() {
        let mut rng = Rng::new(3);
        let k = rand_block(&mut rng, 2, 1, 5, 16);
        let back = corrected(&corrected(&k, &[-7; 5], 1e4), &[7; 5], 1e4);
        for (x, y) in k.data.iter().zip(&back.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_norm_preserved() {
        // Rotations are orthogonal: per-pair norms are invariant.
        quick::check(0x205E, 30, |g| {
            let hd = 2 * g.usize_in(1, 8);
            let t = g.usize_in(1, 6);
            let k = KvBlock::from_data(1, 1, t, hd, g.vec_f32(t * hd, -3.0, 3.0));
            let deltas: Vec<i32> = (0..t).map(|_| g.i64_in(-50, 50) as i32).collect();
            let out = corrected(&k, &deltas, 1e4);
            let half = hd / 2;
            for tok in 0..t {
                let a = k.token_slice(0, 0, tok);
                let b = out.token_slice(0, 0, tok);
                for i in 0..half {
                    let na = a[i] * a[i] + a[i + half] * a[i + half];
                    let nb = b[i] * b[i] + b[i + half] * b[i + half];
                    assert!((na - nb).abs() < 1e-4, "pair {i}: {na} vs {nb}");
                }
            }
        });
    }

    #[test]
    fn matches_python_convention() {
        // Hand-computed: hd=4 (half=2), delta=1, base=10000.
        // inv_freq = [1.0, 10000^-0.5 = 0.01]; angles = [1.0, 0.01].
        let k = KvBlock::from_data(1, 1, 1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let out = corrected(&k, &[1], 1e4);
        let (c0, s0) = (1.0f32.cos(), 1.0f32.sin());
        let (c1, s1) = (0.01f32.cos(), 0.01f32.sin());
        let want = [
            1.0 * c0 - 3.0 * s0,
            2.0 * c1 - 4.0 * s1,
            3.0 * c0 + 1.0 * s0,
            4.0 * c1 + 2.0 * s1,
        ];
        for (x, y) in out.data.iter().zip(&want) {
            assert!((x - y).abs() < 1e-6, "{x} vs {y}");
        }
    }
}
