"""AOT pipeline checks: manifest integrity and HLO text well-formedness.

These tests only run meaningfully after `make artifacts`; they skip if
the artifacts directory is absent (e.g. a fresh checkout running pytest
before the build).
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_counts(manifest):
    assert len(manifest["models"]) == 2
    names = {m["name"] for m in manifest["models"]}
    assert names == {"internvl3_sim", "qwen3vl_sim"}
    # per model: vit buckets + embed + prefill buckets + incr grid + decode
    from compile.configs import MODELS
    want = sum(
        len(c.vit_buckets) + 1 + len(c.prefill_buckets)
        + len(c.incr_new_buckets) * len(c.incr_old_buckets) + 1
        for c in MODELS.values()
    )
    assert len(manifest["artifacts"]) == want


def test_artifact_files_exist_and_parse(manifest):
    for a in manifest["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(4096)
        # HLO text modules start with "HloModule"
        assert head.startswith("HloModule"), a["file"]
        # every artifact has at least one parameter instruction
        assert "parameter(0)" in head or "parameter" in head


def test_manifest_io_specs(manifest):
    for a in manifest["artifacts"]:
        assert a["params"], a["name"]
        assert a["inputs"] and a["outputs"]
        for spec in a["inputs"] + a["outputs"]:
            assert spec["dtype"] in ("f32", "i32")
            assert all(d > 0 for d in spec["shape"])


def test_weights_files_match_manifest(manifest):
    from compile import params as P
    from compile.configs import MODELS
    for m in manifest["models"]:
        path = os.path.join(ART, m["weights"])
        assert os.path.exists(path)
        loaded = P.load_weights(path)
        cfg = MODELS[m["name"]]
        want = P.make_params(cfg)
        assert list(loaded) == list(want)
        # every artifact's param names resolve in the weights file
        for a in manifest["artifacts"]:
            if a["model"] == m["name"]:
                for n in a["params"]:
                    assert n in loaded, (a["name"], n)


def test_golden_fixtures_exist(manifest):
    for m in manifest["models"]:
        path = os.path.join(ART, "golden", f"{m['name']}.json")
        assert os.path.exists(path)
        with open(path) as f:
            g = json.load(f)
        assert {"vit_encode", "prefill_full", "rope_correct"} <= set(g)


def test_prompt_ids_in_manifest(manifest):
    for m in manifest["models"]:
        ids = m["prompt_ids"]
        assert len(ids) == m["text_len"]
        assert all(0 <= i < m["vocab"] for i in ids)
        assert m["yes_token"] != m["no_token"]
