"""Sanity checks on the L1 block-geometry estimator (perf pass tool)."""

from compile.kernels.estimate import sweep, BlockChoice


def test_sweep_nonempty_and_sorted():
    rows = sweep()
    assert rows
    scores = [r.score() for r in rows]
    assert scores == sorted(scores, reverse=True)


def test_vmem_within_budget_for_production_blocks():
    c = BlockChoice(block_q=48, block_k=48, heads=6, t_q=336, t_k=336, head_dim=32)
    assert c.vmem_ok()
    # double buffering costs more than single
    assert c.vmem_bytes(True) > c.vmem_bytes(False)


def test_mxu_prefers_larger_tiles():
    small = BlockChoice(8, 8, 6, 336, 336, 32)
    large = BlockChoice(112, 112, 6, 336, 336, 32)
    assert large.mxu_utilization() > small.mxu_utilization()


def test_intensity_grows_with_block_q():
    # Larger q tiles amortize the K/V stream over more rows.
    lo = BlockChoice(8, 48, 6, 336, 336, 32)
    hi = BlockChoice(112, 48, 6, 336, 336, 32)
    assert hi.intensity() > lo.intensity()


def test_flops_invariant_to_blocking():
    a = BlockChoice(8, 8, 6, 336, 336, 32)
    b = BlockChoice(48, 112, 6, 336, 336, 32)
    assert a.flops() == b.flops()
