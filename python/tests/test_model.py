"""L2 model invariants.

The critical one is exact incremental-prefill consistency: when the
reused KV states are bit-exact (same window, no pruning),
`prefill_incr` must equal the tail of `prefill_full`. This is the
correctness foundation the selective-KVC-refresh approximation is
measured against.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.configs import INTERNVL3_SIM, QWEN3VL_SIM, prompt_ids
from compile import model as M, params as P
from compile.kernels import ref

CFGS = [INTERNVL3_SIM, QWEN3VL_SIM]


def _llm_params(cfg):
    params = P.make_params(cfg)
    return [jnp.asarray(params[n]) for n in P.llm_param_names(cfg)]


def _vit_params(cfg):
    params = P.make_params(cfg)
    return [jnp.asarray(params[n]) for n in P.vit_param_names(cfg)]


@pytest.fixture(scope="module", params=[c.name for c in CFGS])
def cfg(request):
    return {c.name: c for c in CFGS}[request.param]


def test_vit_shapes(cfg):
    plist = _vit_params(cfg)
    rng = np.random.default_rng(0)
    for n in cfg.vit_buckets:
        patches = jnp.asarray(rng.standard_normal((n, cfg.patch_dim)),
                              jnp.float32)
        pos = jnp.arange(n, dtype=jnp.int32) % cfg.patches_per_frame
        mask = jnp.ones((n,), jnp.float32)
        out = M.vit_encode(cfg, plist, patches, pos, mask, use_pallas=False)
        assert out.shape == (n // 4, cfg.llm_dim)
        assert np.isfinite(np.asarray(out)).all()


def test_vit_padding_does_not_leak(cfg):
    """Padded patches must not change the tokens of real patches."""
    plist = _vit_params(cfg)
    rng = np.random.default_rng(1)
    n_real = cfg.vit_buckets[0]
    n_pad = cfg.vit_buckets[1]
    patches = rng.standard_normal((n_real, cfg.patch_dim)).astype(np.float32)
    pos = np.arange(n_real, dtype=np.int32)

    small = M.vit_encode(
        cfg, plist, jnp.asarray(patches), jnp.asarray(pos),
        jnp.ones((n_real,), jnp.float32), use_pallas=False)

    patches_padded = np.concatenate(
        [patches, rng.standard_normal((n_pad - n_real, cfg.patch_dim))
         .astype(np.float32) * 100.0])  # garbage in padding
    pos_padded = np.concatenate([pos, np.zeros(n_pad - n_real, np.int32)])
    mask = np.concatenate([np.ones(n_real, np.float32),
                           np.zeros(n_pad - n_real, np.float32)])
    padded = M.vit_encode(
        cfg, plist, jnp.asarray(patches_padded), jnp.asarray(pos_padded),
        jnp.asarray(mask), use_pallas=False)

    np.testing.assert_allclose(np.asarray(small),
                               np.asarray(padded)[: n_real // 4],
                               atol=1e-4, rtol=1e-4)


def test_prefill_incr_matches_full_exact_reuse(cfg):
    plist = _llm_params(cfg)
    rng = np.random.default_rng(2)
    t, to = 96, 48
    tn = t - to
    emb = jnp.asarray(rng.standard_normal((t, cfg.llm_dim)) * 0.1, jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)
    mask = jnp.ones((t,), jnp.float32)
    last, pooled, logits, k, v = M.prefill_full(cfg, plist, emb, pos, mask,
                                                jnp.int32(t - 1), use_pallas=False)
    last2, pooled2, logits2, kn, vn = M.prefill_incr(
        cfg, plist, emb[to:], pos[to:], mask[to:],
        k[:, :, :to], v[:, :, :to], mask[:to], jnp.int32(tn - 1),
        use_pallas=False)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(last), np.asarray(last2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(k[:, :, to:]), np.asarray(kn),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v[:, :, to:]), np.asarray(vn),
                               atol=1e-4, rtol=1e-4)


def test_prefill_padding_does_not_leak(cfg):
    """Bucket padding (mask=0 tail) must not change valid-token output."""
    plist = _llm_params(cfg)
    rng = np.random.default_rng(3)
    t_real, t_pad = 96, 192
    emb = rng.standard_normal((t_real, cfg.llm_dim)).astype(np.float32) * 0.1
    small = M.prefill_full(
        cfg, plist, jnp.asarray(emb),
        jnp.arange(t_real, dtype=jnp.int32),
        jnp.ones((t_real,), jnp.float32), jnp.int32(t_real - 1),
        use_pallas=False)
    emb_p = np.concatenate(
        [emb, np.full((t_pad - t_real, cfg.llm_dim), 7.0, np.float32)])
    mask = np.concatenate([np.ones(t_real, np.float32),
                           np.zeros(t_pad - t_real, np.float32)])
    pos = np.concatenate([np.arange(t_real, dtype=np.int32),
                          np.zeros(t_pad - t_real, np.int32)])
    padded = M.prefill_full(cfg, plist, jnp.asarray(emb_p), jnp.asarray(pos),
                            jnp.asarray(mask), jnp.int32(t_real - 1),
                            use_pallas=False)
    np.testing.assert_allclose(np.asarray(small[2]), np.asarray(padded[2]),
                               atol=1e-4, rtol=1e-4)  # logits
    np.testing.assert_allclose(np.asarray(small[1]), np.asarray(padded[1]),
                               atol=1e-4, rtol=1e-4)  # pooled (mask-invariant)
    np.testing.assert_allclose(np.asarray(small[3]),
                               np.asarray(padded[3])[:, :, :t_real],
                               atol=1e-4, rtol=1e-4)  # K


def test_rope_correction_layer0_exact(cfg):
    """Layer-0 K depends only on the token's own embedding + position,
    so eq. 5 correction reproduces it *exactly* after a window shift.
    (Deeper layers drift — that is the paper's motivation for anchors.)"""
    plist = _llm_params(cfg)
    rng = np.random.default_rng(4)
    t, shift = 48, 8
    emb = jnp.asarray(rng.standard_normal((t, cfg.llm_dim)) * 0.1, jnp.float32)
    mask = jnp.ones((t,), jnp.float32)
    # window t-1: tokens at positions shift..t+shift
    pos_old = jnp.arange(shift, t + shift, dtype=jnp.int32)
    _, _, _, k_old, _ = M.prefill_full(cfg, plist, emb, pos_old, mask,
                                       jnp.int32(t - 1), use_pallas=False)
    # window t: same tokens now at positions 0..t
    pos_new = jnp.arange(t, dtype=jnp.int32)
    _, _, _, k_new, _ = M.prefill_full(cfg, plist, emb, pos_new, mask,
                                       jnp.int32(t - 1), use_pallas=False)
    delta = pos_new - pos_old  # = -shift
    corrected = ref.rope_correct(k_old[0], delta, cfg.rope_base)
    np.testing.assert_allclose(np.asarray(k_new[0]), np.asarray(corrected),
                               atol=1e-4, rtol=1e-4)


def test_decode_step_matches_prefill_extension(cfg):
    """decode_step(tok) == prefill_full over [ctx ++ tok] at the last
    position (same logits), with the cache laid out in decode slots."""
    plist_e = _llm_params(cfg)
    params = P.make_params(cfg)
    plist_d = [jnp.asarray(params[n])
               for n in P.llm_param_names(cfg, embed=True)]
    rng = np.random.default_rng(5)
    t = 48
    tok = 7
    tok_emb = jnp.asarray(params["llm.tok_embed"][tok])
    emb = jnp.asarray(rng.standard_normal((t, cfg.llm_dim)) * 0.1, jnp.float32)
    full_emb = jnp.concatenate([emb, tok_emb[None, :]])
    pos = jnp.arange(t + 1, dtype=jnp.int32)
    mask = jnp.ones((t + 1,), jnp.float32)
    _, _, logits_full, _, _ = M.prefill_full(
        cfg, plist_e, full_emb, pos, mask, jnp.int32(t), use_pallas=False)

    _, _, _, k, v = M.prefill_full(
        cfg, plist_e, emb, pos[:t], mask[:t], jnp.int32(t - 1),
        use_pallas=False)
    slots = cfg.decode_slots
    kc = np.zeros((cfg.llm_layers, cfg.llm_heads, slots, cfg.head_dim),
                  np.float32)
    vc = np.zeros_like(kc)
    kc[:, :, :t] = np.asarray(k)
    vc[:, :, :t] = np.asarray(v)
    cm = np.zeros((slots,), np.float32)
    cm[:t] = 1.0
    logits_dec, k_new, v_new = M.decode_step(
        cfg, plist_d, jnp.int32(tok), jnp.int32(t), jnp.asarray(kc),
        jnp.asarray(vc), jnp.asarray(cm))
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), atol=1e-4, rtol=1e-4)
    assert k_new.shape == (cfg.llm_layers, cfg.llm_heads, cfg.head_dim)


def test_pallas_and_ref_paths_agree(cfg):
    """Model-level: the whole prefill with the Pallas kernel matches the
    jnp-oracle path."""
    plist = _llm_params(cfg)
    rng = np.random.default_rng(6)
    t = 96
    emb = jnp.asarray(rng.standard_normal((t, cfg.llm_dim)) * 0.1, jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)
    mask = jnp.ones((t,), jnp.float32)
    a = M.prefill_full(cfg, plist, emb, pos, mask, jnp.int32(t - 1),
                       use_pallas=False)
    b = M.prefill_full(cfg, plist, emb, pos, mask, jnp.int32(t - 1),
                       use_pallas=True)
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]),
                               atol=5e-4, rtol=5e-4)


def test_prompt_ids_deterministic(cfg):
    a, b = prompt_ids(cfg), prompt_ids(cfg)
    assert a == b
    assert len(a) == cfg.text_len
    assert all(0 <= i < cfg.vocab for i in a)


def test_weights_roundtrip(tmp_path, cfg):
    params = P.make_params(cfg)
    path = tmp_path / "w.bin"
    P.save_weights(path, params)
    loaded = P.load_weights(path)
    assert list(loaded) == list(params)
    for n in params:
        np.testing.assert_array_equal(params[n], loaded[n])
