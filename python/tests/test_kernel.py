"""L1 kernel correctness: Pallas fused attention vs the pure-jnp oracle.

The hypothesis sweep is the primary correctness signal for the kernel:
random shapes (heads, query/key lengths, head dims), random block
geometries, and adversarial masks (fully-blocked rows, ragged validity)
must all match ref.attention to f32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention, _pick_block

ATOL = 2e-5
RTOL = 2e-5


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def _run_case(h, tq, tk, hd, bq, bk, mask_kind, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (h, tq, hd))
    k = _rand(rng, (h, tk, hd))
    v = _rand(rng, (h, tk, hd))
    if mask_kind == "none":
        bias = jnp.zeros((tq, tk), jnp.float32)
    elif mask_kind == "causal":
        m = np.tril(np.ones((tq, tk), np.float32), k=tk - tq)
        bias = jnp.where(jnp.asarray(m) > 0, 0.0, ref.NEG_INF)
    elif mask_kind == "random":
        m = rng.random((tq, tk)) > 0.4
        bias = jnp.where(jnp.asarray(m), 0.0, ref.NEG_INF).astype(jnp.float32)
    else:  # "ragged": trailing keys invalid, like bucket padding
        valid = rng.integers(1, tk + 1)
        m = np.zeros((tq, tk), np.float32)
        m[:, :valid] = 1.0
        bias = jnp.where(jnp.asarray(m) > 0, 0.0, ref.NEG_INF)
    scale = float(hd) ** -0.5
    want = ref.attention(q, k, v, bias, scale)
    got = attention(q, k, v, bias, scale, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               atol=ATOL, rtol=RTOL)


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(1, 6),
    tq=st.sampled_from([8, 16, 32, 48, 96]),
    tk=st.sampled_from([8, 16, 32, 48, 96, 144]),
    hd=st.sampled_from([8, 16, 32]),
    bq=st.sampled_from([8, 16, 32, 64]),
    bk=st.sampled_from([8, 16, 32, 64]),
    mask_kind=st.sampled_from(["none", "causal", "random", "ragged"]),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_sweep(h, tq, tk, hd, bq, bk, mask_kind, seed):
    _run_case(h, tq, tk, hd, bq, bk, mask_kind, seed)


@pytest.mark.parametrize("tq,tk", [(48, 96), (96, 336), (16, 64)])
def test_production_shapes(tq, tk):
    """The exact shapes the AOT buckets use."""
    _run_case(6, tq, tk, 32, 64, 64, "causal", 7)


def test_fully_masked_rows_are_finite():
    """Bucket-padding query rows see an all-blocked bias; the online
    softmax must not emit NaN/Inf (the model discards these rows, but
    NaN would poison downstream reductions in HLO)."""
    h, tq, tk, hd = 2, 16, 32, 8
    rng = np.random.default_rng(3)
    q = _rand(rng, (h, tq, hd))
    k = _rand(rng, (h, tk, hd))
    v = _rand(rng, (h, tk, hd))
    bias = jnp.full((tq, tk), ref.NEG_INF, jnp.float32)
    out = attention(q, k, v, bias, hd ** -0.5, block_q=8, block_k=8)
    assert np.isfinite(np.asarray(out)).all()


def test_pick_block_divides():
    for t in [16, 48, 96, 144, 272, 336, 352]:
        for pref in [8, 16, 64, 128]:
            b = _pick_block(t, pref)
            assert t % b == 0 and 1 <= b <= pref


def test_block_shape_invariance():
    """Output must not depend on block geometry (pure tiling)."""
    rng = np.random.default_rng(11)
    h, tq, tk, hd = 3, 48, 96, 16
    q = _rand(rng, (h, tq, hd))
    k = _rand(rng, (h, tk, hd))
    v = _rand(rng, (h, tk, hd))
    bias = jnp.zeros((tq, tk), jnp.float32)
    outs = [np.asarray(attention(q, k, v, bias, hd ** -0.5,
                                 block_q=bq, block_k=bk))
            for bq, bk in [(8, 8), (16, 32), (48, 96), (64, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=ATOL, rtol=RTOL)


def test_rope_compose():
    """R(a+b) == R(a) applied after R(b) — the identity eq. 5 relies on."""
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 8, 16))
    pa = jnp.asarray(np.arange(8), jnp.int32)
    pb = jnp.asarray(np.full(8, 3), jnp.int32)
    lhs = ref.apply_rope(x, pa + pb, 10000.0)
    rhs = ref.apply_rope(ref.apply_rope(x, pa, 10000.0), pb, 10000.0)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               atol=1e-5, rtol=1e-5)


def test_rope_correct_negative_delta():
    """Correcting by -p undoes rope entirely."""
    rng = np.random.default_rng(6)
    x = _rand(rng, (2, 8, 16))
    p = jnp.asarray(np.arange(8), jnp.int32)
    roped = ref.apply_rope(x, p, 10000.0)
    back = ref.rope_correct(roped, -p, 10000.0)
    np.testing.assert_allclose(np.asarray(x), np.asarray(back),
                               atol=1e-5, rtol=1e-5)
