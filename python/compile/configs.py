"""Model configurations for the CodecFlow reproduction.

Two synthetic VLM configs stand in for InternVL3-14B and Qwen3-VL-32B
(see DESIGN.md §3 for the substitution rationale). Every artifact the
rust runtime loads is described here: the shape buckets are the static
shapes we AOT-compile; the rust side selects the smallest bucket that
fits and pads with validity masks.

The numbers are chosen so that a full window prefill is ~1 GFLOP —
large enough that pruning/reuse visibly moves wall-clock on the CPU
PJRT backend, small enough that the full experiment grid runs in
minutes.
"""

from dataclasses import dataclass, field
from typing import List


@dataclass(frozen=True)
class ModelConfig:
    name: str
    # --- video / patch geometry -------------------------------------
    frame: int = 64          # frame side length (pixels, luma plane)
    patch: int = 8           # ViT patch side length
    merge: int = 2           # spatial merge factor (2x2 patches -> 1 token)
    window_frames: int = 20  # frames per sliding window
    # --- ViT encoder --------------------------------------------------
    vit_dim: int = 128
    vit_layers: int = 4
    vit_heads: int = 4
    vit_mlp: int = 4         # MLP expansion factor
    # --- LLM backbone -------------------------------------------------
    llm_dim: int = 192
    llm_layers: int = 5
    llm_heads: int = 6
    head_dim: int = 32
    llm_mlp: int = 4
    vocab: int = 64
    text_len: int = 16       # fixed prompt length (tokens)
    rope_base: float = 10000.0
    # --- AOT shape buckets ---------------------------------------------
    # patches per frame fed to the ViT (multiples of merge**2 = 4)
    vit_buckets: List[int] = field(default_factory=lambda: [16, 32, 48, 64])
    # total sequence length for full prefill
    prefill_buckets: List[int] = field(default_factory=lambda: [96, 192, 288, 336])
    # (new-token, reused-token) bucket grid for incremental prefill
    incr_new_buckets: List[int] = field(default_factory=lambda: [48, 96, 144, 192, 240])
    incr_old_buckets: List[int] = field(default_factory=lambda: [96, 192, 288])
    # KV slots for the decode step (window tokens + generated answer)
    decode_slots: int = 352
    max_decode_tokens: int = 4
    seed: int = 0

    @property
    def grid(self) -> int:
        """Patch grid side (patches per frame row)."""
        return self.frame // self.patch

    @property
    def patches_per_frame(self) -> int:
        return self.grid * self.grid

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch

    @property
    def tokens_per_frame(self) -> int:
        return self.patches_per_frame // (self.merge * self.merge)

    @property
    def max_visual_tokens(self) -> int:
        return self.window_frames * self.tokens_per_frame

    @property
    def max_seq(self) -> int:
        return self.max_visual_tokens + self.text_len


INTERNVL3_SIM = ModelConfig(
    name="internvl3_sim",
    vit_dim=128, vit_layers=4, vit_heads=4,
    llm_dim=192, llm_layers=5, llm_heads=6, head_dim=32,
    seed=1234,
)

QWEN3VL_SIM = ModelConfig(
    name="qwen3vl_sim",
    vit_dim=192, vit_layers=5, vit_heads=6,
    llm_dim=256, llm_layers=6, llm_heads=8, head_dim=32,
    seed=5678,
)

MODELS = {m.name: m for m in (INTERNVL3_SIM, QWEN3VL_SIM)}

# Prompt token ids for the anomaly query template (fixed-length, small
# vocab). Mirrors "Describe the frames and determine if they show any
# abuse. Start your response with 'Yes' or 'No'." hashed into the tiny
# vocab; ids 1 and 2 are reserved for the "Yes" / "No" answer tokens.
YES_TOKEN = 1
NO_TOKEN = 2


def prompt_ids(cfg: ModelConfig) -> List[int]:
    import zlib
    words = ("describe the frames and determine if they show any "
             "abuse start your response with yes or no").split()
    # crc32, not hash(): hash() is salted per process and the prompt must
    # be identical between the AOT pass and the rust runtime.
    ids = [3 + (zlib.crc32(w.encode()) % (cfg.vocab - 3)) for w in words]
    ids = ids[: cfg.text_len]
    while len(ids) < cfg.text_len:
        ids.append(0)
    return ids
