"""L2: the JAX VLM compute graph (build-time only).

Five artifact families, each lowered to HLO text by `aot.py`:

  vit_encode    patches -> merged visual tokens (pruned patch set only)
  embed_text    prompt token ids -> embeddings
  prefill_full  embeddings -> last hidden, logits, full KV cache
  prefill_incr  NEW embeddings + REUSED (position-corrected) KV ->
                last hidden, logits, KV for the new tokens only
  decode_step   one generated token -> logits + its KV entry

All functions take `params` as an *ordered list* of arrays (the order is
the manifest contract with the rust runtime — see params.py) followed by
activations. Attention dispatches to the L1 Pallas kernel
(`use_pallas=True`, the AOT default) or the pure-jnp oracle.

`prefill_incr` is the heart of the paper's selective KVC refresh: the
rust KVC Reuser hands it cached keys already rotated to their new
positions (eq. 5 applied host-side), the new tokens (fresh frames +
I-frame anchors + prompt) are computed from scratch, and they attend
over the concatenation. When the reused states are bit-exact
(no pruning, same context) `prefill_incr` equals the tail of
`prefill_full` — a property enforced by tests/test_model.py.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import attention as pallas_attention
from . import params as P

NEG_INF = ref.NEG_INF


def _attn(q, k, v, bias, scale, use_pallas):
    if use_pallas:
        return pallas_attention(q, k, v, bias, scale)
    return ref.attention(q, k, v, bias, scale)


def _named(cfg, names, plist):
    assert len(names) == len(plist), (len(names), len(plist))
    return dict(zip(names, plist))


def _split_heads(x, heads, hd):
    # [T, H*hd] -> [H, T, hd]
    t = x.shape[0]
    return x.reshape(t, heads, hd).transpose(1, 0, 2)


def _merge_heads(x):
    # [H, T, hd] -> [T, H*hd]
    h, t, hd = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * hd)


# ---------------------------------------------------------------------
# ViT encoder (+ 2x2 spatial-merge projector)
# ---------------------------------------------------------------------

def vit_encode(cfg: ModelConfig, plist, patches, pos_ids, mask, use_pallas=True):
    """Encode a pruned patch set of one frame into visual tokens.

    patches: [Np, patch_dim] f32 — Np is a shape bucket; only the
      retained (dynamic) patches, ordered group-by-group so that each
      consecutive run of merge**2 patches is one spatial merge group.
    pos_ids: [Np] i32 — position of each patch in the full frame grid
      (0..patches_per_frame), indexes the learned pos embedding.
    mask:    [Np] f32 — 1 for real patches, 0 for bucket padding.

    Returns: [Np / merge**2, llm_dim] merged visual tokens (rows whose
      group was padding are garbage and dropped by the caller).
    """
    p = _named(cfg, P.vit_param_names(cfg), plist)
    vd, heads = cfg.vit_dim, cfg.vit_heads
    hd = vd // heads
    npatch = patches.shape[0]

    h = patches @ p["vit.patch_embed.w"] + p["vit.patch_embed.b"]
    h = h + jnp.take(p["vit.pos_embed"], pos_ids, axis=0)

    # Bidirectional attention over retained patches; padding blocked.
    bias = jnp.where(mask[None, :] > 0, 0.0, NEG_INF)
    bias = jnp.broadcast_to(bias, (npatch, npatch)).astype(jnp.float32)
    scale = float(hd) ** -0.5

    for i in range(cfg.vit_layers):
        pre = f"vit.layer{i}."
        x = ref.layer_norm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
        qkv = x @ p[pre + "attn.wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        o = _attn(_split_heads(q, heads, hd), _split_heads(k, heads, hd),
                  _split_heads(v, heads, hd), bias, scale, use_pallas)
        h = h + _merge_heads(o) @ p[pre + "attn.wo"]
        x = ref.layer_norm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = h + ref.gelu(x @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"]) @ \
            p[pre + "mlp.w2"] + p[pre + "mlp.b2"]

    h = ref.layer_norm(h, p["vit.ln_f.g"], p["vit.ln_f.b"])
    # Spatial merge: merge**2 consecutive patches -> one token.
    g = cfg.merge * cfg.merge
    merged = h.reshape(npatch // g, g * vd)
    return merged @ p["proj.w"] + p["proj.b"]


# ---------------------------------------------------------------------
# Text embedding
# ---------------------------------------------------------------------

def embed_text(cfg: ModelConfig, plist, ids):
    """ids: [S] i32 -> [S, llm_dim]."""
    (tok_embed,) = plist
    return jnp.take(tok_embed, ids, axis=0)


# ---------------------------------------------------------------------
# LLM prefill (full and incremental)
# ---------------------------------------------------------------------

def _llm_layer(cfg, p, pre, h, pos, bias, old_k=None, old_v=None,
               use_pallas=True):
    """One decoder layer over the *new* tokens h.

    If old_k/old_v are given ([H, To, hd], position-corrected, rope
    already applied), new tokens attend over [old ++ new]; bias must be
    [Tn, To+Tn]. Returns (h, k_new, v_new) with rope-applied k_new.
    """
    heads, hd = cfg.llm_heads, cfg.head_dim
    scale = float(hd) ** -0.5
    x = ref.layer_norm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
    q = ref.apply_rope(_split_heads(x @ p[pre + "attn.wq"], heads, hd),
                       pos, cfg.rope_base)
    k = ref.apply_rope(_split_heads(x @ p[pre + "attn.wk"], heads, hd),
                       pos, cfg.rope_base)
    v = _split_heads(x @ p[pre + "attn.wv"], heads, hd)
    if old_k is not None:
        k_all = jnp.concatenate([old_k, k], axis=1)
        v_all = jnp.concatenate([old_v, v], axis=1)
    else:
        k_all, v_all = k, v
    o = _attn(q, k_all, v_all, bias, scale, use_pallas)
    h = h + _merge_heads(o) @ p[pre + "attn.wo"]
    x = ref.layer_norm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
    h = h + ref.gelu(x @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"]) @ \
        p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
    return h, k, v


def _readout(cfg, p, h, last_idx, mask=None):
    h = ref.layer_norm(h, p["llm.ln_f.g"], p["llm.ln_f.b"])
    last = jnp.take(h, last_idx, axis=0, mode="clip")
    logits = last @ p["llm.unembed"]
    if mask is None:
        pooled = jnp.mean(h, axis=0)
    else:
        # Masked mean over valid positions: the aggregate readout the
        # anomaly probe consumes (DESIGN.md §4). For incremental
        # prefill this pools the recomputed block, whose hidden states
        # attend over the reused KV — so reuse-induced drift and
        # pruning-induced token loss both flow through it.
        pooled = jnp.sum(h * mask[:, None], axis=0) / jnp.maximum(
            jnp.sum(mask), 1.0)
    return last, pooled, logits


def prefill_full(cfg: ModelConfig, plist, emb, pos, mask, last_idx,
                 use_pallas=True):
    """Full-window prefill.

    emb: [T, d] (visual tokens ++ prompt embeddings, bucket-padded)
    pos: [T] i32 sequence positions; mask: [T] validity;
    last_idx: scalar i32, index of the last valid token.

    Returns (last_hidden [d], pooled [d], logits [V], K [L,H,T,hd],
    V [L,H,T,hd]).
    Cached K carries rope (the position it was computed at) — the rust
    KVC Reuser corrects it with eq. 5 on window advance.
    """
    p = _named(cfg, P.llm_param_names(cfg), plist)
    t = emb.shape[0]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    allowed = causal * mask[None, :]
    bias = jnp.where(allowed > 0, 0.0, NEG_INF).astype(jnp.float32)

    h, ks, vs = emb, [], []
    for i in range(cfg.llm_layers):
        h, k, v = _llm_layer(cfg, p, f"llm.layer{i}.", h, pos, bias,
                             use_pallas=use_pallas)
        ks.append(k)
        vs.append(v)
    last, pooled, logits = _readout(cfg, p, h, last_idx, mask)
    return last, pooled, logits, jnp.stack(ks), jnp.stack(vs)


def prefill_incr(cfg: ModelConfig, plist, new_emb, new_pos, new_mask,
                 old_k, old_v, old_mask, last_idx, use_pallas=True):
    """Incremental prefill with reused KV (selective KVC refresh).

    new_emb/new_pos/new_mask: [Tn, ...] the tokens recomputed this
      window (fresh frames + I-frame anchors + prompt), bucket-padded.
    old_k/old_v: [L, H, To, hd] reused entries, keys already
      position-corrected to the current window.
    old_mask: [To] validity of reused slots.
    last_idx: index of last valid token *within the new block*.

    Returns (last_hidden, pooled, logits, K_new [L,H,Tn,hd], V_new) —
    the rust side splices the KV after the reused entries to form the
    full cache.
    """
    p = _named(cfg, P.llm_param_names(cfg), plist)
    tn = new_emb.shape[0]
    to = old_k.shape[2]
    causal = jnp.tril(jnp.ones((tn, tn), jnp.float32)) * new_mask[None, :]
    bias_old = jnp.where(old_mask[None, :] > 0, 0.0, NEG_INF)
    bias_old = jnp.broadcast_to(bias_old, (tn, to))
    bias_new = jnp.where(causal > 0, 0.0, NEG_INF)
    bias = jnp.concatenate([bias_old, bias_new], axis=1).astype(jnp.float32)

    h, ks, vs = new_emb, [], []
    for i in range(cfg.llm_layers):
        h, k, v = _llm_layer(cfg, p, f"llm.layer{i}.", h, new_pos, bias,
                             old_k=old_k[i], old_v=old_v[i],
                             use_pallas=use_pallas)
        ks.append(k)
        vs.append(v)
    last, pooled, logits = _readout(cfg, p, h, last_idx, new_mask)
    return last, pooled, logits, jnp.stack(ks), jnp.stack(vs)


# ---------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------

def decode_step(cfg: ModelConfig, plist, tok_id, pos, k_cache, v_cache,
                cache_mask):
    """One autoregressive step (answer-token generation).

    tok_id, pos: scalars (i32). k_cache/v_cache: [L, H, S, hd] with S =
    cfg.decode_slots; cache_mask: [S]. Returns (logits [V],
    k_new [L,H,hd], v_new [L,H,hd]) — the rust side writes the new entry
    into its host-resident cache.

    Single-token attention is tiny; uses the jnp path (no Pallas).
    """
    p = _named(cfg, P.llm_param_names(cfg, embed=True), plist)
    heads, hd = cfg.llm_heads, cfg.head_dim
    scale = float(hd) ** -0.5
    pos_v = jnp.reshape(pos, (1,))

    h = jnp.take(p["llm.tok_embed"], jnp.reshape(tok_id, (1,)), axis=0)
    bias = jnp.where(cache_mask[None, :] > 0, 0.0, NEG_INF).astype(jnp.float32)
    bias_self = jnp.zeros((1, 1), jnp.float32)
    ks, vs = [], []
    for i in range(cfg.llm_layers):
        pre = f"llm.layer{i}."
        x = ref.layer_norm(h, p[pre + "ln1.g"], p[pre + "ln1.b"])
        q = ref.apply_rope(_split_heads(x @ p[pre + "attn.wq"], heads, hd),
                           pos_v, cfg.rope_base)
        k = ref.apply_rope(_split_heads(x @ p[pre + "attn.wk"], heads, hd),
                           pos_v, cfg.rope_base)
        v = _split_heads(x @ p[pre + "attn.wv"], heads, hd)
        k_all = jnp.concatenate([k_cache[i], k], axis=1)
        v_all = jnp.concatenate([v_cache[i], v], axis=1)
        b = jnp.concatenate([bias, bias_self], axis=1)
        o = ref.attention(q, k_all, v_all, b, scale)
        h = h + _merge_heads(o) @ p[pre + "attn.wo"]
        x = ref.layer_norm(h, p[pre + "ln2.g"], p[pre + "ln2.b"])
        h = h + ref.gelu(x @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"]) @ \
            p[pre + "mlp.w2"] + p[pre + "mlp.b2"]
        ks.append(k[:, 0, :])
        vs.append(v[:, 0, :])
    _, _, logits = _readout(cfg, p, h, jnp.int32(0))
    return logits, jnp.stack(ks), jnp.stack(vs)
