"""Deterministic parameter generation and serialization.

Weights are *inputs* to every AOT artifact (not baked constants): HLO
text with megabytes of inlined f32 constants would be unusably large,
and the rust runtime can hold them as device-resident PjRtBuffers and
pass them by reference per call (`execute_b`), so the per-request cost
is zero after startup.

The flattening order here is a contract with the rust side: the
manifest records, per artifact, the ordered parameter-name list, and
`weights_<model>.bin` stores each named tensor once. Format (little
endian):

    magic   b"CFWB"            4 bytes
    version u32 = 1
    count   u32
    then per tensor:
      name_len u32, name bytes (utf-8)
      dtype    u32 (0 = f32, 1 = i32)
      ndim     u32, dims u32 * ndim
      data     raw bytes (dtype * prod(dims))
"""

import struct
from collections import OrderedDict

import numpy as np

from .configs import ModelConfig

MAGIC = b"CFWB"


def _init(rng, shape, fan_in):
    return (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)


def make_params(cfg: ModelConfig) -> "OrderedDict[str, np.ndarray]":
    """All model parameters, deterministically from cfg.seed."""
    rng = np.random.default_rng(cfg.seed)
    p = OrderedDict()

    vd, ld, hd = cfg.vit_dim, cfg.llm_dim, cfg.head_dim
    # --- ViT ---------------------------------------------------------
    p["vit.patch_embed.w"] = _init(rng, (cfg.patch_dim, vd), cfg.patch_dim)
    p["vit.patch_embed.b"] = np.zeros((vd,), np.float32)
    p["vit.pos_embed"] = _init(rng, (cfg.patches_per_frame, vd), 4)
    for i in range(cfg.vit_layers):
        pre = f"vit.layer{i}."
        p[pre + "ln1.g"] = np.ones((vd,), np.float32)
        p[pre + "ln1.b"] = np.zeros((vd,), np.float32)
        p[pre + "attn.wqkv"] = _init(rng, (vd, 3 * vd), vd)
        p[pre + "attn.wo"] = _init(rng, (vd, vd), vd)
        p[pre + "ln2.g"] = np.ones((vd,), np.float32)
        p[pre + "ln2.b"] = np.zeros((vd,), np.float32)
        p[pre + "mlp.w1"] = _init(rng, (vd, cfg.vit_mlp * vd), vd)
        p[pre + "mlp.b1"] = np.zeros((cfg.vit_mlp * vd,), np.float32)
        p[pre + "mlp.w2"] = _init(rng, (cfg.vit_mlp * vd, vd), cfg.vit_mlp * vd)
        p[pre + "mlp.b2"] = np.zeros((vd,), np.float32)
    p["vit.ln_f.g"] = np.ones((vd,), np.float32)
    p["vit.ln_f.b"] = np.zeros((vd,), np.float32)
    # 2x2 spatial merge projector: concat(4 * vd) -> llm_dim
    merge_in = cfg.merge * cfg.merge * vd
    p["proj.w"] = _init(rng, (merge_in, ld), merge_in)
    p["proj.b"] = np.zeros((ld,), np.float32)

    # --- LLM ---------------------------------------------------------
    p["llm.tok_embed"] = _init(rng, (cfg.vocab, ld), 4)
    qkv_dim = cfg.llm_heads * hd
    for i in range(cfg.llm_layers):
        pre = f"llm.layer{i}."
        p[pre + "ln1.g"] = np.ones((ld,), np.float32)
        p[pre + "ln1.b"] = np.zeros((ld,), np.float32)
        p[pre + "attn.wq"] = _init(rng, (ld, qkv_dim), ld)
        p[pre + "attn.wk"] = _init(rng, (ld, qkv_dim), ld)
        p[pre + "attn.wv"] = _init(rng, (ld, qkv_dim), ld)
        p[pre + "attn.wo"] = _init(rng, (qkv_dim, ld), qkv_dim)
        p[pre + "ln2.g"] = np.ones((ld,), np.float32)
        p[pre + "ln2.b"] = np.zeros((ld,), np.float32)
        p[pre + "mlp.w1"] = _init(rng, (ld, cfg.llm_mlp * ld), ld)
        p[pre + "mlp.b1"] = np.zeros((cfg.llm_mlp * ld,), np.float32)
        p[pre + "mlp.w2"] = _init(rng, (cfg.llm_mlp * ld, ld), cfg.llm_mlp * ld)
        p[pre + "mlp.b2"] = np.zeros((ld,), np.float32)
    p["llm.ln_f.g"] = np.ones((ld,), np.float32)
    p["llm.ln_f.b"] = np.zeros((ld,), np.float32)
    p["llm.unembed"] = _init(rng, (ld, cfg.vocab), ld)
    return p


# Parameter subsets per artifact family (order matters — it is the HLO
# parameter order and the manifest contract with rust).
def vit_param_names(cfg: ModelConfig):
    names = ["vit.patch_embed.w", "vit.patch_embed.b", "vit.pos_embed"]
    for i in range(cfg.vit_layers):
        pre = f"vit.layer{i}."
        names += [pre + s for s in (
            "ln1.g", "ln1.b", "attn.wqkv", "attn.wo",
            "ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2")]
    names += ["vit.ln_f.g", "vit.ln_f.b", "proj.w", "proj.b"]
    return names


def llm_param_names(cfg: ModelConfig, embed=False):
    names = ["llm.tok_embed"] if embed else []
    for i in range(cfg.llm_layers):
        pre = f"llm.layer{i}."
        names += [pre + s for s in (
            "ln1.g", "ln1.b", "attn.wq", "attn.wk", "attn.wv", "attn.wo",
            "ln2.g", "ln2.b", "mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2")]
    names += ["llm.ln_f.g", "llm.ln_f.b", "llm.unembed"]
    return names


def save_weights(path, params):
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(params)))
        for name, arr in params.items():
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            dtype = 0 if arr.dtype == np.float32 else 1
            f.write(struct.pack("<I", dtype))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load_weights(path):
    """Inverse of save_weights (used by python tests)."""
    out = OrderedDict()
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC
        _ver, count = struct.unpack("<II", f.read(8))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            dtype, ndim = struct.unpack("<II", f.read(8))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            np_dtype = np.float32 if dtype == 0 else np.int32
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype=np_dtype).reshape(dims)
            out[name] = data
    return out
