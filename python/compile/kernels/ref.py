"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything in this file is the *correctness ground truth*: the Pallas
kernels in `attention.py` must match these functions to float32
tolerance (enforced by `python/tests/test_kernel.py`), and the L2 model
uses these implementations when `use_pallas=False` so model-level tests
can isolate kernel bugs from model bugs.
"""

import jax.numpy as jnp

NEG_INF = -1e9


def attention(q, k, v, bias, scale):
    """Multi-head scaled dot-product attention with an additive bias.

    Args:
      q: [H, Tq, hd] queries.
      k: [H, Tk, hd] keys.
      v: [H, Tk, hd] values.
      bias: [Tq, Tk] additive mask (0 for allowed, NEG_INF for blocked),
        shared across heads.
      scale: softmax temperature (typically hd ** -0.5).

    Returns:
      [H, Tq, hd] attention output.
    """
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale + bias[None, :, :]
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", e / l, v)


def rope_angles(positions, head_dim, base):
    """Rotary angles for integer positions: [T, hd/2]."""
    half = head_dim // 2
    inv_freq = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[:, None] * inv_freq[None, :]


def apply_rope(x, positions, base):
    """Apply rotary position embedding (half-split convention).

    Args:
      x: [H, T, hd]; positions: [T] int32; base: rope theta base.
    Returns: [H, T, hd] rotated.
    """
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, base)          # [T, hd/2]
    cos, sin = jnp.cos(ang)[None], jnp.sin(ang)[None]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope_correct(k, delta, base):
    """Rotate cached keys by a position delta (paper eq. 5).

    k: [H, T, hd] cached keys (already carrying their old positions);
    delta: [T] int32, new_pos - old_pos per token.
    Equivalent to apply_rope(k, delta) because rotations compose:
    R(p_new) = R(p_new - p_old) . R(p_old).
    """
    return apply_rope(k, delta, base)


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))
