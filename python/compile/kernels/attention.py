"""L1 Pallas kernel: fused multi-head attention with online softmax.

This is the compute hot-spot of the whole stack — every ViT layer and
every LLM prefill layer calls it. The structure is the TPU re-expression
of the paper's A100 attention path (DESIGN.md §7):

  * grid = (heads, query tiles): each program instance owns one
    (head, q-tile) pair and keeps the running softmax state
    (m, l, acc) live for the whole K sweep;
  * K/V are swept in `block_k`-sized chunks with `pl.dslice`, the
    HBM->VMEM streaming schedule that CUDA flash-attention expresses
    with threadblocks (BlockSpec pins the per-head K/V panel, the inner
    fori_loop walks it chunk by chunk);
  * the two matmuls per chunk are full-tile `jnp.dot`s with f32
    accumulation so the Mosaic path would map them onto the MXU
    systolic array.

`interpret=True` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel lowers to plain HLO (a while loop
over k-chunks) which both the python tests and the rust runtime run.
Real-TPU efficiency is estimated from the block geometry in
`estimate.py` (see EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale, block_k):
    """One (head, q-tile) program instance.

    Block shapes (leading head axis already peeled by BlockSpec):
      q_ref:    [block_q, hd]
      k_ref:    [Tk, hd]   (per-head panel; streamed in block_k chunks)
      v_ref:    [Tk, hd]
      bias_ref: [block_q, Tk]
      o_ref:    [block_q, hd]
    """
    q = q_ref[...] * scale
    block_q, hd = q.shape
    tk = k_ref.shape[0]
    nk = tk // block_k

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k_chunk = k_ref[pl.dslice(i * block_k, block_k), :]
        v_chunk = v_ref[pl.dslice(i * block_k, block_k), :]
        b_chunk = bias_ref[:, pl.dslice(i * block_k, block_k)]
        # MXU matmul #1: scores for this chunk.
        s = jnp.dot(q, k_chunk.T, preferred_element_type=jnp.float32) + b_chunk
        # Online softmax update (flash-attention recurrence).
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        # MXU matmul #2: weighted values, rescaled accumulator.
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v_chunk, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, hd), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    # Fully-masked (padding) query rows still accumulate exp(0) mass, so
    # l >= 1 always and the divide is safe.
    o_ref[...] = acc / l[:, None]


def _pick_block(t, preferred):
    """Largest divisor of t that is <= preferred (t is bucket-sized)."""
    b = min(preferred, t)
    while t % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("scale", "block_q", "block_k"))
def attention(q, k, v, bias, scale, block_q=128, block_k=128):
    # Default block preference 128 (picks 112 for the 336-token
    # buckets): the estimate.py sweep scores it highest — VMEM 245 KiB
    # (2% of budget, double-buffered), best MXU occupancy and 34.5
    # FLOPs/HBM-byte vs 18.9 at 48x48. See EXPERIMENTS.md §Perf.
    """Fused MHA; drop-in for ref.attention (same signature semantics).

    q: [H, Tq, hd], k/v: [H, Tk, hd], bias: [Tq, Tk] additive.
    """
    h, tq, hd = q.shape
    tk = k.shape[1]
    bq = _pick_block(tq, block_q)
    bk = _pick_block(tk, block_k)

    grid = (h, tq // bq)
    kernel = functools.partial(_attn_kernel, scale=scale, block_k=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, tk, hd), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, tk, hd), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((bq, tk), lambda hi, qi: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, hd), jnp.float32),
        interpret=True,
    )(q, k, v, bias)
