"""L1 perf: VMEM-footprint + MXU-utilization estimator for the Pallas
attention kernel's block geometry.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
kernel is optimized *structurally* (DESIGN.md §7/§8): the estimator
scores a (block_q, block_k) choice by

  * VMEM residency: q tile + k/v chunks + bias tile + softmax state
    must fit comfortably in ~16 MiB VMEM (with double-buffering
    headroom for the k/v streams);
  * MXU occupancy: matmul tiles should fill the 128x128 systolic array
    — penalize tiles whose M/N/K dims are far below 128 lanes or not
    8-aligned (f32 sublane packing);
  * arithmetic intensity: FLOPs per HBM byte moved, assuming K/V are
    streamed once per q-tile sweep.

Run: python -m compile.kernels.estimate   (prints the sweep table the
EXPERIMENTS.md §Perf L1 section records).
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU = 128
F32 = 4


@dataclass
class BlockChoice:
    block_q: int
    block_k: int
    heads: int
    t_q: int
    t_k: int
    head_dim: int

    # ---- VMEM ---------------------------------------------------------
    def vmem_bytes(self, double_buffer=True):
        """Peak VMEM per program instance (one head, one q tile)."""
        q = self.block_q * self.head_dim * F32
        kv_chunk = 2 * self.block_k * self.head_dim * F32
        if double_buffer:
            kv_chunk *= 2  # next chunk prefetched while computing
        bias = self.block_q * self.t_k * F32
        state = self.block_q * (2 + self.head_dim) * F32  # m, l, acc
        out = self.block_q * self.head_dim * F32
        return q + kv_chunk + bias + state + out

    def vmem_ok(self):
        return self.vmem_bytes() < VMEM_BYTES // 4  # leave headroom

    # ---- MXU ----------------------------------------------------------
    def mxu_utilization(self):
        """Fraction of the systolic array the two matmuls keep busy.

        Matmul 1: [bq, hd] x [hd, bk]; matmul 2: [bq, bk] x [bk, hd].
        The MXU processes 128x128 tiles; occupancy is the product of
        per-dim fill ratios, averaged over the two matmuls.
        """
        def occ(m, k, n):
            fill = lambda d: min(d, MXU) / MXU
            align = 1.0 if m % 8 == 0 and n % 8 == 0 else 0.5
            return fill(m) * fill(n) * min(k / MXU, 1.0) ** 0.5 * align

        m1 = occ(self.block_q, self.head_dim, self.block_k)
        m2 = occ(self.block_q, self.block_k, self.head_dim)
        return (m1 + m2) / 2

    # ---- roofline -------------------------------------------------------
    def flops(self):
        # per (head, q-tile, k-chunk): 2 matmuls of 2*bq*bk*hd
        n_q = self.t_q // self.block_q
        n_k = self.t_k // self.block_k
        return self.heads * n_q * n_k * 2 * (2 * self.block_q * self.block_k * self.head_dim)

    def hbm_bytes(self):
        # q read once; k/v streamed once per q tile; out written once
        n_q = self.t_q // self.block_q
        q_io = self.heads * self.t_q * self.head_dim * F32 * 2  # q + out
        kv_io = self.heads * n_q * self.t_k * self.head_dim * F32 * 2
        bias_io = n_q * self.block_q * self.t_k * F32
        return q_io + kv_io + bias_io

    def intensity(self):
        return self.flops() / self.hbm_bytes()

    def score(self):
        if not self.vmem_ok():
            return 0.0
        return self.mxu_utilization() * min(self.intensity() / 20.0, 1.0)


def sweep(heads=6, t_q=336, t_k=336, head_dim=32):
    rows = []
    for bq in (8, 16, 32, 48, 64, 112):
        if t_q % bq:
            continue
        for bk in (8, 16, 32, 48, 64, 112):
            if t_k % bk:
                continue
            c = BlockChoice(bq, bk, heads, t_q, t_k, head_dim)
            rows.append(c)
    rows.sort(key=lambda c: -c.score())
    return rows


def main():
    print(f"{'bq':>4} {'bk':>4} {'VMEM KiB':>9} {'MXU':>6} {'F/B':>6} {'score':>6}")
    for c in sweep():
        print(
            f"{c.block_q:>4} {c.block_k:>4} {c.vmem_bytes() // 1024:>9}"
            f" {c.mxu_utilization():>6.2f} {c.intensity():>6.1f} {c.score():>6.2f}"
        )


if __name__ == "__main__":
    main()
