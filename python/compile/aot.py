"""AOT pipeline: lower every (artifact x bucket) to HLO text + manifest.

Run once via `make artifacts` (python never executes on the request
path). Outputs, under `artifacts/`:

  <model>/<family>_<bucket>.hlo.txt   HLO text modules (the interchange
                                      format — xla_extension 0.5.1
                                      rejects jax>=0.5 serialized protos,
                                      the text parser reassigns ids)
  weights_<model>.bin                 deterministic parameters (params.py)
  manifest.json                       everything the rust runtime needs:
                                      model dims, artifact files, ordered
                                      parameter lists, input/output specs
  golden/<model>.json                 small input/output vectors for the
                                      rust integration tests (checksums
                                      for large tensors)

Usage: python -m compile.aot --out-dir ../artifacts [--models a,b] [--force]
"""

import argparse
import functools
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import MODELS, prompt_ids, YES_TOKEN, NO_TOKEN
from . import model as M
from . import params as P

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=np.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _iospec(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class ArtifactBuilder:
    def __init__(self, cfg, params, out_dir, force):
        self.cfg = cfg
        self.params = params
        self.out_dir = out_dir
        self.force = force
        self.entries = []
        os.makedirs(os.path.join(out_dir, cfg.name), exist_ok=True)

    def build(self, name, fn, param_names, act_specs, inputs, outputs, bucket):
        """Lower fn(params..., *activations) and record a manifest entry."""
        rel = f"{self.cfg.name}/{name}.hlo.txt"
        path = os.path.join(self.out_dir, rel)
        self.entries.append({
            "model": self.cfg.name,
            "name": name,
            "file": rel,
            "params": param_names,
            "inputs": inputs,
            "outputs": outputs,
            "bucket": bucket,
        })
        if os.path.exists(path) and not self.force:
            return
        pspecs = [_spec(self.params[n].shape, self.params[n].dtype)
                  for n in param_names]
        lowered = jax.jit(fn).lower(*(pspecs + act_specs))
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {rel} ({len(text) // 1024} KiB)")


def build_model_artifacts(cfg, out_dir, force):
    print(f"[{cfg.name}] generating parameters + artifacts")
    params = P.make_params(cfg)
    P.save_weights(os.path.join(out_dir, f"weights_{cfg.name}.bin"), params)
    b = ArtifactBuilder(cfg, params, out_dir, force)

    vit_names = P.vit_param_names(cfg)
    llm_names = P.llm_param_names(cfg)
    llm_embed_names = P.llm_param_names(cfg, embed=True)
    nvp, ld, hd = len(vit_names), cfg.llm_dim, cfg.head_dim
    L, H, V = cfg.llm_layers, cfg.llm_heads, cfg.vocab

    # --- vit_encode buckets -------------------------------------------
    for n in cfg.vit_buckets:
        g = cfg.merge * cfg.merge

        def fn(*args, n=n):
            plist = list(args[:nvp])
            patches, pos_ids, mask = args[nvp:]
            return (M.vit_encode(cfg, plist, patches, pos_ids, mask),)

        b.build(
            f"vit_encode_n{n}", fn, vit_names,
            [_spec((n, cfg.patch_dim)), _spec((n,), np.int32), _spec((n,))],
            inputs=[_iospec("patches", (n, cfg.patch_dim)),
                    _iospec("pos_ids", (n,), I32), _iospec("mask", (n,))],
            outputs=[_iospec("tokens", (n // g, ld))],
            bucket={"n": n},
        )

    # --- embed_text ----------------------------------------------------
    s = cfg.text_len

    def fn_embed(tok_embed, ids):
        return (M.embed_text(cfg, [tok_embed], ids),)

    b.build(
        "embed_text", fn_embed, ["llm.tok_embed"],
        [_spec((s,), np.int32)],
        inputs=[_iospec("ids", (s,), I32)],
        outputs=[_iospec("emb", (s, ld))],
        bucket={"s": s},
    )

    # --- prefill_full buckets -------------------------------------------
    nlp = len(llm_names)
    for t in cfg.prefill_buckets:
        def fn(*args, t=t):
            plist = list(args[:nlp])
            emb, pos, mask, last_idx = args[nlp:]
            return M.prefill_full(cfg, plist, emb, pos, mask, last_idx)

        b.build(
            f"prefill_full_t{t}", fn, llm_names,
            [_spec((t, ld)), _spec((t,), np.int32), _spec((t,)),
             _spec((), np.int32)],
            inputs=[_iospec("emb", (t, ld)), _iospec("pos", (t,), I32),
                    _iospec("mask", (t,)), _iospec("last_idx", (), I32)],
            outputs=[_iospec("last_hidden", (ld,)), _iospec("pooled", (ld,)),
                     _iospec("logits", (V,)),
                     _iospec("k", (L, H, t, hd)), _iospec("v", (L, H, t, hd))],
            bucket={"t": t},
        )

    # --- prefill_incr bucket grid ---------------------------------------
    for tn in cfg.incr_new_buckets:
        for to in cfg.incr_old_buckets:
            def fn(*args, tn=tn, to=to):
                plist = list(args[:nlp])
                (new_emb, new_pos, new_mask, old_k, old_v, old_mask,
                 last_idx) = args[nlp:]
                return M.prefill_incr(cfg, plist, new_emb, new_pos, new_mask,
                                      old_k, old_v, old_mask, last_idx)

            b.build(
                f"prefill_incr_n{tn}_o{to}", fn, llm_names,
                [_spec((tn, ld)), _spec((tn,), np.int32), _spec((tn,)),
                 _spec((L, H, to, hd)), _spec((L, H, to, hd)), _spec((to,)),
                 _spec((), np.int32)],
                inputs=[_iospec("new_emb", (tn, ld)),
                        _iospec("new_pos", (tn,), I32),
                        _iospec("new_mask", (tn,)),
                        _iospec("old_k", (L, H, to, hd)),
                        _iospec("old_v", (L, H, to, hd)),
                        _iospec("old_mask", (to,)),
                        _iospec("last_idx", (), I32)],
                outputs=[_iospec("last_hidden", (ld,)),
                         _iospec("pooled", (ld,)),
                         _iospec("logits", (V,)),
                         _iospec("k_new", (L, H, tn, hd)),
                         _iospec("v_new", (L, H, tn, hd))],
                bucket={"new": tn, "old": to},
            )

    # --- decode_step -----------------------------------------------------
    slots = cfg.decode_slots
    nle = len(llm_embed_names)

    def fn_decode(*args):
        plist = list(args[:nle])
        tok_id, pos, k_cache, v_cache, cache_mask = args[nle:]
        return M.decode_step(cfg, plist, tok_id, pos, k_cache, v_cache,
                             cache_mask)

    b.build(
        "decode_step", fn_decode, llm_embed_names,
        [_spec((), np.int32), _spec((), np.int32),
         _spec((L, H, slots, hd)), _spec((L, H, slots, hd)), _spec((slots,))],
        inputs=[_iospec("tok_id", (), I32), _iospec("pos", (), I32),
                _iospec("k_cache", (L, H, slots, hd)),
                _iospec("v_cache", (L, H, slots, hd)),
                _iospec("cache_mask", (slots,))],
        outputs=[_iospec("logits", (V,)), _iospec("k_new", (L, H, hd)),
                 _iospec("v_new", (L, H, hd))],
        bucket={"slots": slots},
    )

    write_golden(cfg, params, out_dir)
    return b.entries


def _chk(a):
    a = np.asarray(a, np.float64).ravel()
    return {"sum": float(a.sum()), "l2": float(np.sqrt((a * a).sum())),
            "first8": [float(x) for x in a[:8]]}


def write_golden(cfg, params, out_dir):
    """Cross-language fixtures: the rust integration tests execute the
    same artifacts with these inputs and must match these outputs."""
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)
    rng = np.random.default_rng(99)
    g = {"model": cfg.name}

    vit_p = [jnp.asarray(params[n]) for n in P.vit_param_names(cfg)]
    llm_p = [jnp.asarray(params[n]) for n in P.llm_param_names(cfg)]

    n = cfg.vit_buckets[0]
    patches = rng.standard_normal((n, cfg.patch_dim)).astype(np.float32)
    pos_ids = np.arange(n, dtype=np.int32)
    mask = np.ones((n,), np.float32)
    toks = M.vit_encode(cfg, vit_p, jnp.asarray(patches),
                        jnp.asarray(pos_ids), jnp.asarray(mask),
                        use_pallas=True)
    g["vit_encode"] = {
        "bucket": n,
        "patches": patches.ravel().tolist(),
        "pos_ids": pos_ids.tolist(),
        "mask": mask.tolist(),
        "tokens": np.asarray(toks).ravel().tolist(),
    }

    t = cfg.prefill_buckets[0]
    emb = (rng.standard_normal((t, cfg.llm_dim)) * 0.1).astype(np.float32)
    pos = np.arange(t, dtype=np.int32)
    m = np.ones((t,), np.float32)
    last, pooled, logits, k, v = M.prefill_full(
        cfg, llm_p, jnp.asarray(emb), jnp.asarray(pos), jnp.asarray(m),
        jnp.int32(t - 1), use_pallas=True)
    g["prefill_full"] = {
        "bucket": t,
        "emb": emb.ravel().tolist(),
        "last_hidden": np.asarray(last).ravel().tolist(),
        "pooled": np.asarray(pooled).ravel().tolist(),
        "logits": np.asarray(logits).ravel().tolist(),
        "k_check": _chk(k), "v_check": _chk(v),
    }

    # rope correction fixture: rotate K by a delta, verify vs recompute.
    hk = np.asarray(k)[:, :, : 8, :]  # [L, H, 8, hd]
    delta = np.full((8,), -3, np.int32)
    from .kernels import ref
    rot = np.stack([
        np.asarray(ref.rope_correct(jnp.asarray(hk[l]), jnp.asarray(delta),
                                    cfg.rope_base))
        for l in range(hk.shape[0])
    ])
    g["rope_correct"] = {
        "k_in": hk.ravel().tolist(), "delta": int(delta[0]),
        "shape": list(hk.shape), "k_out": rot.ravel().tolist(),
        "rope_base": cfg.rope_base,
    }

    with open(os.path.join(out_dir, "golden", f"{cfg.name}.json"), "w") as f:
        json.dump(g, f)
    print(f"  wrote golden/{cfg.name}.json")


def model_manifest_entry(cfg):
    return {
        "name": cfg.name,
        "weights": f"weights_{cfg.name}.bin",
        "frame": cfg.frame, "patch": cfg.patch, "merge": cfg.merge,
        "grid": cfg.grid, "patches_per_frame": cfg.patches_per_frame,
        "patch_dim": cfg.patch_dim, "tokens_per_frame": cfg.tokens_per_frame,
        "window_frames": cfg.window_frames,
        "vit_dim": cfg.vit_dim, "vit_layers": cfg.vit_layers,
        "vit_heads": cfg.vit_heads, "vit_mlp": cfg.vit_mlp,
        "llm_dim": cfg.llm_dim, "llm_layers": cfg.llm_layers,
        "llm_heads": cfg.llm_heads, "head_dim": cfg.head_dim,
        "llm_mlp": cfg.llm_mlp, "vocab": cfg.vocab,
        "text_len": cfg.text_len, "rope_base": cfg.rope_base,
        "vit_buckets": cfg.vit_buckets,
        "prefill_buckets": cfg.prefill_buckets,
        "incr_new_buckets": cfg.incr_new_buckets,
        "incr_old_buckets": cfg.incr_old_buckets,
        "decode_slots": cfg.decode_slots,
        "max_decode_tokens": cfg.max_decode_tokens,
        "prompt_ids": prompt_ids(cfg),
        "yes_token": YES_TOKEN, "no_token": NO_TOKEN,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "models": [], "artifacts": []}
    for name in args.models.split(","):
        cfg = MODELS[name]
        manifest["models"].append(model_manifest_entry(cfg))
        manifest["artifacts"] += build_model_artifacts(cfg, args.out_dir,
                                                       args.force)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts, "
          f"{len(manifest['models'])} models")


if __name__ == "__main__":
    main()
